//! KKT-condition verification, used by tests and the property harness.

use crate::kernel::KernelEval;

/// Summary of how far an α vector is from the paper's Constraint (3)/(5).
#[derive(Debug, Clone)]
pub struct KktReport {
    /// m(α) − M(α): the maximal violating-pair gap. ≤ ε at optimality.
    pub max_violation: f64,
    /// Σ yᵢαᵢ (must be 0 up to rounding).
    pub sum_y_alpha: f64,
    /// Worst box-constraint breach (negative α or α−C overshoot); 0 if none.
    pub box_breach: f64,
    /// Estimated bias from the free SVs (paper's b).
    pub bias: f64,
}

/// Evaluate the KKT conditions of `alpha` for the C-SVC dual on `eval`.
///
/// Recomputes the gradient from scratch (O(n_sv·n) kernel evaluations) —
/// this is a *verification* tool, not a production path.
pub fn kkt_violation(eval: &KernelEval, alpha: &[f64], c: f64) -> KktReport {
    let n = eval.len();
    assert_eq!(alpha.len(), n);
    let y = &eval.ds.y;

    // G_i = Σ_j α_j Q_ij − 1
    let mut g = vec![-1.0f64; n];
    for j in 0..n {
        if alpha[j] != 0.0 {
            let coef = alpha[j] * y[j];
            for t in 0..n {
                g[t] += y[t] * coef * eval.eval(j, t);
            }
        }
    }

    // m(α) = max_{I_up} −yG ; M(α) = min_{I_low} −yG
    let mut m = f64::NEG_INFINITY;
    let mut big_m = f64::INFINITY;
    let mut free_sum = 0.0;
    let mut free_cnt = 0usize;
    for t in 0..n {
        let v = -y[t] * g[t];
        let in_up = (y[t] > 0.0 && alpha[t] < c) || (y[t] < 0.0 && alpha[t] > 0.0);
        let in_low = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < c);
        if in_up {
            m = m.max(v);
        }
        if in_low {
            big_m = big_m.min(v);
        }
        if alpha[t] > 0.0 && alpha[t] < c {
            free_sum += y[t] * g[t];
            free_cnt += 1;
        }
    }

    let sum_y_alpha: f64 = alpha.iter().zip(y).map(|(a, yy)| a * yy).sum();
    let box_breach = alpha
        .iter()
        .map(|&a| (-a).max(a - c).max(0.0))
        .fold(0.0, f64::max);
    let bias = if free_cnt > 0 {
        free_sum / free_cnt as f64
    } else {
        (m + big_m) / 2.0
    };

    KktReport {
        max_violation: m - big_m,
        sum_y_alpha,
        box_breach,
        bias,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DataMatrix, Dataset};
    use crate::kernel::Kernel;
    use crate::smo::{SmoParams, Solver};

    #[test]
    fn zero_alpha_violates_when_separable() {
        let ds = Dataset::new(
            "v",
            DataMatrix::dense(2, 1, vec![-1.0, 1.0]),
            vec![-1.0, 1.0],
        );
        let eval = KernelEval::new(ds, Kernel::Linear);
        let rep = kkt_violation(&eval, &[0.0, 0.0], 1.0);
        // cold start: m − M = 1 − (−1) = 2
        assert!((rep.max_violation - 2.0).abs() < 1e-12);
        assert_eq!(rep.sum_y_alpha, 0.0);
    }

    #[test]
    fn solved_alpha_passes() {
        let ds = crate::data::synth::generate("heart", Some(60), 21);
        let eval = KernelEval::new(ds, Kernel::rbf(0.2));
        let mut solver = Solver::new(eval.clone(), SmoParams::with_c(3.0));
        let r = solver.solve();
        let rep = kkt_violation(&eval, &r.alpha, 3.0);
        assert!(rep.max_violation <= 1.5e-3, "violation {}", rep.max_violation);
        assert!(rep.box_breach == 0.0);
        assert!((rep.bias - r.b).abs() < 1e-6);
    }

    #[test]
    fn box_breach_detected() {
        let ds = Dataset::new(
            "b",
            DataMatrix::dense(2, 1, vec![-1.0, 1.0]),
            vec![-1.0, 1.0],
        );
        let eval = KernelEval::new(ds, Kernel::Linear);
        let rep = kkt_violation(&eval, &[1.5, 1.5], 1.0);
        assert!((rep.box_breach - 0.5).abs() < 1e-12);
    }
}
