//! The SMO solver core: the binary C-SVC fast path ([`Solver`]) and the
//! QP-problem abstraction ([`QpProblem`]/[`QpSpec`]/[`GeneralSolver`])
//! that extends the same decomposition method to ε-SVR and one-class SVM.

use super::active::{partition_of, reconstruct_inactive, ActiveSet, VarBound};
use crate::data::Dataset;
use crate::kernel::{CacheDtype, KernelCache, KernelEval};
use std::time::Instant;

/// Solver hyper-parameters.
#[derive(Debug, Clone)]
pub struct SmoParams {
    /// Penalty C (box constraint upper bound).
    pub c: f64,
    /// Stopping tolerance on the maximal KKT violation (LibSVM default 1e-3).
    pub eps: f64,
    /// Hard iteration cap (safety net; LibSVM caps at 10⁷-ish).
    pub max_iter: u64,
    /// Enable LibSVM-style shrinking.
    pub shrinking: bool,
    /// Kernel-row cache budget in bytes.
    pub cache_bytes: usize,
    /// Worker threads for the warm-start gradient initialisation (kernel
    /// row blocks + the gradient sweep): 0 = auto (machine parallelism),
    /// 1 = sequential. The parallel sweep performs bit-identical
    /// arithmetic for every thread count, so this knob never changes the
    /// solution — only wall-clock time. The SMO iteration loop itself
    /// stays sequential (it is an inherently sequential coordinate
    /// method).
    pub threads: usize,
    /// Storage precision of cached kernel rows. The default
    /// [`CacheDtype::F64`] keeps every bit-identity guarantee;
    /// [`CacheDtype::F32`] halves the cache footprint (rows round through
    /// f32 while all gradient/objective accumulation stays f64), trading
    /// exactness for capacity — results are epsilon-close, as pinned by
    /// `tests/kernel_identity.rs`.
    pub cache_dtype: CacheDtype,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams {
            c: 1.0,
            eps: 1e-3,
            max_iter: 20_000_000,
            shrinking: true,
            cache_bytes: 256 << 20,
            threads: 0,
            cache_dtype: CacheDtype::F64,
        }
    }
}

impl SmoParams {
    /// Defaults with the given penalty C.
    pub fn with_c(c: f64) -> SmoParams {
        SmoParams {
            c,
            ..Default::default()
        }
    }
}

/// Outcome of one SMO solve.
#[derive(Debug, Clone)]
pub struct SmoResult {
    /// Optimal dual weights, one per training instance.
    pub alpha: Vec<f64>,
    /// Bias of the hyperplane: the paper's b (= LibSVM's ρ). The decision
    /// function is  sign(Σᵢ yᵢαᵢK(xᵢ,x) − b).
    pub b: f64,
    /// SMO iterations actually performed — the hardware-independent cost
    /// measure reported in the paper's Table 1.
    pub iterations: u64,
    /// Dual objective value ½αᵀQα − Σα (LibSVM's obj).
    pub objective: f64,
    /// Support vectors (αᵢ > 0).
    pub n_sv: usize,
    /// Bounded support vectors (αᵢ = C).
    pub n_bsv: usize,
    /// Whether the solver hit `max_iter` before reaching tolerance.
    pub converged: bool,
    /// Wall time spent computing the initial gradient (non-zero only for
    /// warm starts; part of the seeding cost accounting).
    pub grad_init_secs: f64,
    /// Final gradient Gᵢ = Σⱼ αⱼQᵢⱼ − 1. The paper's optimality indicator
    /// is fᵢ = yᵢ·Gᵢ; the seeding algorithms consume it.
    pub g: Vec<f64>,
    /// Terminal free/lower/upper partition of the dual variables against
    /// the box — the solver's active-set knowledge, exported so the next
    /// cross-validation round can carry it forward (see
    /// [`Seeder::seed_active_set`](crate::seeding::Seeder::seed_active_set)
    /// and [`ActiveSet::seeded`](super::ActiveSet::seeded)).
    pub partition: Vec<VarBound>,
    /// Number of shrink passes the solve ran (periodic scans plus a
    /// seeded initialisation that removed variables); 0 whenever
    /// shrinking is disabled. Diagnostic only.
    pub shrink_passes: u64,
}

impl SmoResult {
    /// The paper's optimality indicators fᵢ = yᵢ·Gᵢ over the training set.
    pub fn f_indicators(&self, y: &[f64]) -> Vec<f64> {
        self.g.iter().zip(y).map(|(g, y)| g * y).collect()
    }
}

const TAU: f64 = 1e-12;

/// Support vectors per parallel kernel-row block in the warm-start
/// gradient (bounds peak pinned-row memory at `ROW_BLOCK·n·8` bytes).
const ROW_BLOCK: usize = 64;
/// Below this problem size the parallel gradient path is not worth the
/// thread hand-off; the sequential loop (identical arithmetic) runs.
const PAR_MIN_N: usize = 256;

/// One SMO solve over a fixed training set. Owns the kernel cache; reuse
/// across solves on the same data by calling [`Solver::solve_from`] again.
pub struct Solver {
    cache: KernelCache,
    y: Vec<f64>,
    params: SmoParams,
}

impl Solver {
    /// Bind a solver to a training set (labels come from `eval.ds.y`).
    pub fn new(eval: KernelEval, params: SmoParams) -> Solver {
        let y = eval.ds.y.clone();
        let cache =
            KernelCache::with_byte_budget_dtype(eval, params.cache_bytes, params.cache_dtype);
        Solver { cache, y, params }
    }

    /// The solver's hyper-parameters.
    pub fn params(&self) -> &SmoParams {
        &self.params
    }

    /// Mutable access to the kernel-row cache (reused across re-solves).
    pub fn cache(&mut self) -> &mut KernelCache {
        &mut self.cache
    }

    /// Number of training instances.
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Solve from the zero start (LibSVM cold start).
    pub fn solve(&mut self) -> SmoResult {
        let n = self.n();
        self.solve_from(vec![0.0; n], None)
    }

    /// Solve from a seeded α. `initial_g` may carry a pre-computed gradient
    /// Gᵢ = Σⱼ αⱼQᵢⱼ − 1 (e.g. from the XLA bulk backend); otherwise it is
    /// computed here natively.
    ///
    /// The initial α must be feasible: 0 ≤ αᵢ ≤ C. (Σyα = 0 is the seeders'
    /// contract; it is asserted in debug builds.)
    pub fn solve_from(&mut self, alpha: Vec<f64>, initial_g: Option<Vec<f64>>) -> SmoResult {
        self.solve_seeded(alpha, initial_g, None)
    }

    /// [`Solver::solve_from`] plus an optional **carried active-set
    /// guess**: `inactive_seed` lists variable positions believed to be
    /// bounded and non-violating (typically the previous CV round's
    /// bounded partition mapped through the fold transition). The guess
    /// is validated index-by-index against the initial gradient before
    /// any variable is shrunk ([`ActiveSet::seeded`]), and the usual
    /// final unshrink + full-KKT re-check still runs, so a wrong guess
    /// can only cost iterations — the converged model never depends on
    /// it. Ignored when `params.shrinking` is off.
    pub fn solve_seeded(
        &mut self,
        alpha: Vec<f64>,
        initial_g: Option<Vec<f64>>,
        inactive_seed: Option<&[usize]>,
    ) -> SmoResult {
        let n = self.n();
        assert_eq!(alpha.len(), n);
        let c = self.params.c;
        debug_assert!(
            alpha.iter().all(|&a| (-1e-9..=c + 1e-9).contains(&a)),
            "seeded alpha violates box constraints"
        );
        debug_assert!(
            alpha.iter().zip(&self.y).map(|(a, y)| a * y).sum::<f64>().abs() < 1e-6 * c * n as f64,
            "seeded alpha violates sum y·alpha = 0"
        );

        let grad_start = Instant::now();
        let mut g = match initial_g {
            Some(g) => {
                assert_eq!(g.len(), n);
                g
            }
            None => self.compute_gradient(&alpha),
        };
        let grad_init_secs = grad_start.elapsed().as_secs_f64();

        let mut alpha = alpha;
        // The shared active-set core: start from the carried-over guess
        // when one is provided (validated against the fresh gradient),
        // from the full set otherwise.
        let mut active = match inactive_seed {
            Some(guess) if self.params.shrinking && !guess.is_empty() => ActiveSet::seeded(
                n,
                &self.y,
                &alpha,
                &g,
                c,
                self.params.eps,
                guess,
            ),
            _ => ActiveSet::full(n),
        };
        let mut iter: u64 = 0;
        let mut converged = false;

        loop {
            if iter >= self.params.max_iter {
                break;
            }

            // Periodic shrinking.
            if self.params.shrinking && active.tick() {
                active.shrink(&self.y, &alpha, &g, c, self.params.eps);
            }

            // Working-set selection on the active set.
            let sel = self.select_working_set(active.indices(), &alpha, &g);
            let (i, j, m_minus_big_m) = match sel {
                Some(sel) => sel,
                None => {
                    // Optimal on the active set. If shrunk, reconstruct and
                    // retry globally once before declaring convergence.
                    if !active.is_full() {
                        self.reconstruct_gradient(&alpha, &mut g, active.indices());
                        active.unshrink();
                        match self.select_working_set(active.indices(), &alpha, &g) {
                            Some(_) => continue,
                            None => {
                                converged = true;
                                break;
                            }
                        }
                    }
                    converged = true;
                    break;
                }
            };
            let _ = m_minus_big_m;

            iter += 1;

            // Two-variable subproblem (LibSVM update, f64 throughout).
            let (yi, yj) = (self.y[i], self.y[j]);
            let (kii, kjj) = (self.cache.value(i, i), self.cache.value(j, j));
            let kij = self.cache.value(i, j);
            let mut quad = kii + kjj - 2.0 * kij;
            if quad <= 0.0 {
                quad = TAU;
            }

            let (old_ai, old_aj) = (alpha[i], alpha[j]);
            if yi != yj {
                let delta = (-g[i] - g[j]) / quad;
                let diff = alpha[i] - alpha[j];
                alpha[i] += delta;
                alpha[j] += delta;
                if diff > 0.0 {
                    if alpha[j] < 0.0 {
                        alpha[j] = 0.0;
                        alpha[i] = diff;
                    }
                } else if alpha[i] < 0.0 {
                    alpha[i] = 0.0;
                    alpha[j] = -diff;
                }
                if diff > 0.0 {
                    if alpha[i] > c {
                        alpha[i] = c;
                        alpha[j] = c - diff;
                    }
                } else if alpha[j] > c {
                    alpha[j] = c;
                    alpha[i] = c + diff;
                }
            } else {
                let delta = (g[i] - g[j]) / quad;
                let sum = alpha[i] + alpha[j];
                alpha[i] -= delta;
                alpha[j] += delta;
                if sum > c {
                    if alpha[i] > c {
                        alpha[i] = c;
                        alpha[j] = sum - c;
                    }
                } else if alpha[j] < 0.0 {
                    alpha[j] = 0.0;
                    alpha[i] = sum;
                }
                if sum > c {
                    if alpha[j] > c {
                        alpha[j] = c;
                        alpha[i] = sum - c;
                    }
                } else if alpha[i] < 0.0 {
                    alpha[i] = 0.0;
                    alpha[j] = sum;
                }
            }

            // Gradient update over the active set:
            // G_t += Q_ti·Δαᵢ + Q_tj·Δαⱼ,  Q_ti = y_t·yᵢ·K_ti.
            let dai = alpha[i] - old_ai;
            let daj = alpha[j] - old_aj;
            if dai != 0.0 || daj != 0.0 {
                let ci = yi * dai;
                let cj = yj * daj;
                let (row_i, row_j) = self.cache.row_pair(i, j);
                // Hoist the dtype match out of the sweep: the f64 tier runs
                // the exact historical arithmetic (bit-identity pin).
                match (row_i.as_f64(), row_j.as_f64()) {
                    (Some(ri), Some(rj)) => {
                        for &t in active.indices() {
                            g[t] += self.y[t] * (ci * ri[t] + cj * rj[t]);
                        }
                    }
                    _ => {
                        for &t in active.indices() {
                            g[t] += self.y[t] * (ci * row_i.get(t) + cj * row_j.get(t));
                        }
                    }
                }
            }
        }

        // Ensure g is globally consistent (it may be stale for shrunk
        // indices if we stopped at max_iter while shrunk).
        if !active.is_full() {
            self.reconstruct_gradient(&alpha, &mut g, active.indices());
        }

        // Bias (paper's b = LibSVM ρ) from the final gradient.
        let b = self.compute_bias(&alpha, &g);

        // Dual objective ½·Σᵢ αᵢ(Gᵢ − 1)  (since G = Qα − 1).
        let objective = 0.5
            * alpha
                .iter()
                .zip(&g)
                .map(|(&a, &gi)| a * (gi - 1.0))
                .sum::<f64>();

        let n_sv = alpha.iter().filter(|&&a| a > 0.0).count();
        let n_bsv = alpha.iter().filter(|&&a| a >= c).count();
        let partition = partition_of(&alpha, c);

        SmoResult {
            alpha,
            b,
            iterations: iter,
            objective,
            n_sv,
            n_bsv,
            converged,
            grad_init_secs,
            g,
            partition,
            shrink_passes: active.passes(),
        }
    }

    /// Gᵢ = Σⱼ αⱼQᵢⱼ − 1, computed from the support vectors only.
    ///
    /// For warm starts with enough work this runs in parallel: support
    /// vectors are processed in kernel-row *blocks* (rows of a block are
    /// evaluated concurrently through the cache), and the gradient sweep
    /// over t is chunked across threads. Every `g[t]` accumulates its
    /// terms in the same (ascending-j) order as the sequential loop, so
    /// the result is **bit-identical** for any `params.threads`.
    pub fn compute_gradient(&mut self, alpha: &[f64]) -> Vec<f64> {
        let n = self.n();
        let threads = crate::util::pool::effective_threads(self.params.threads);
        let mut g = vec![-1.0f64; n];
        let svs: Vec<usize> = (0..n).filter(|&j| alpha[j] > 0.0).collect();
        if threads <= 1 || n < PAR_MIN_N || svs.len() < 2 {
            for &j in &svs {
                let coef = alpha[j] * self.y[j];
                let row = self.cache.row_arc(j);
                match row.as_f64() {
                    Some(r) => {
                        for t in 0..n {
                            g[t] += self.y[t] * coef * r[t];
                        }
                    }
                    None => {
                        for t in 0..n {
                            g[t] += self.y[t] * coef * row.get(t);
                        }
                    }
                }
            }
            return g;
        }
        let chunk = (n / (threads * 4)).max(64);
        for block in svs.chunks(ROW_BLOCK) {
            let rows = self.cache.rows_block(block, threads);
            let y = &self.y;
            crate::util::pool::par_chunks_mut(threads, &mut g, chunk, |_c, start, piece| {
                for (off, gt) in piece.iter_mut().enumerate() {
                    let t = start + off;
                    let mut acc = *gt;
                    for (bj, &j) in block.iter().enumerate() {
                        let coef = alpha[j] * y[j];
                        acc += y[t] * coef * rows[bj].get(t);
                    }
                    *gt = acc;
                }
            });
        }
        g
    }

    /// WSS2: returns (i, j) or None when the active set is ε-optimal.
    fn select_working_set(
        &mut self,
        active: &[usize],
        alpha: &[f64],
        g: &[f64],
    ) -> Option<(usize, usize, f64)> {
        let c = self.params.c;
        // i = argmax_{t ∈ I_up} −y_t·G_t
        let mut gmax = f64::NEG_INFINITY;
        let mut i = usize::MAX;
        for &t in active {
            let in_up = (self.y[t] > 0.0 && alpha[t] < c) || (self.y[t] < 0.0 && alpha[t] > 0.0);
            if in_up {
                let v = -self.y[t] * g[t];
                if v >= gmax {
                    gmax = v;
                    i = t;
                }
            }
        }
        if i == usize::MAX {
            return None;
        }

        // j: second-order selection over I_low with violation. The row is
        // pinned as an owned refcounted row, so `diag` below (which may
        // touch the cache) can't invalidate it — this replaced an `unsafe`
        // raw-slice borrow.
        let row_i = self.cache.row_arc(i);
        let kii = row_i.get(i);

        let mut gmin = f64::INFINITY; // M(α)
        let mut obj_min = f64::INFINITY;
        let mut j = usize::MAX;
        for &t in active {
            let in_low = (self.y[t] > 0.0 && alpha[t] > 0.0) || (self.y[t] < 0.0 && alpha[t] < c);
            if !in_low {
                continue;
            }
            let v = -self.y[t] * g[t];
            if v < gmin {
                gmin = v;
            }
            let b_it = gmax - v; // violation margin
            if b_it > 0.0 {
                // Curvature along the SMO pair direction (Δαᵢ = yᵢ·η,
                // Δα_t = −y_t·η): dᵀQd/η² = K_ii + K_tt − 2·K_it — raw
                // kernel values, the label signs cancel (LibSVM's
                // quad_coef in both label branches).
                let ktt = self.diag(t);
                let mut a_it = kii + ktt - 2.0 * row_i.get(t);
                if a_it <= 0.0 {
                    a_it = TAU;
                }
                let dec = -(b_it * b_it) / a_it;
                if dec <= obj_min {
                    obj_min = dec;
                    j = t;
                }
            }
        }

        if gmax - gmin < self.params.eps || j == usize::MAX {
            return None;
        }
        Some((i, j, gmax - gmin))
    }

    /// K(t,t); O(1) for RBF (=1), computed otherwise.
    #[inline]
    fn diag(&mut self, t: usize) -> f64 {
        match self.cache.kernel() {
            crate::kernel::Kernel::Rbf { .. } => 1.0,
            _ => self.cache.value(t, t),
        }
    }

    /// Recompute G for every index outside `active` from scratch (the
    /// LibSVM `reconstruct_gradient`, without the G̅ incremental trick:
    /// reconstruction is rare — once per unshrink). Delegates to the
    /// shared [`active`](super::active) core with p = −1, signs = y and
    /// the identity kernel-row map.
    fn reconstruct_gradient(&mut self, alpha: &[f64], g: &mut [f64], active: &[usize]) {
        let cache = &mut self.cache;
        let y = &self.y;
        reconstruct_inactive(g, active, |_| -1.0, y, alpha, |t| t, |j| cache.row_arc(j));
    }

    /// ρ/b from the final gradient: average of yᵢGᵢ over free SVs, or the
    /// midpoint of the bound brackets when no free SV exists.
    fn compute_bias(&self, alpha: &[f64], g: &[f64]) -> f64 {
        let c = self.params.c;
        let mut free_sum = 0.0;
        let mut free_count = 0usize;
        let (mut ub, mut lb) = (f64::INFINITY, f64::NEG_INFINITY);
        for t in 0..alpha.len() {
            let yg = self.y[t] * g[t];
            if alpha[t] > 0.0 && alpha[t] < c {
                free_sum += yg;
                free_count += 1;
            } else {
                let in_up =
                    (self.y[t] > 0.0 && alpha[t] <= 0.0) || (self.y[t] < 0.0 && alpha[t] >= c);
                if in_up {
                    ub = ub.min(yg);
                } else {
                    lb = lb.max(yg);
                }
            }
        }
        if free_count > 0 {
            free_sum / free_count as f64
        } else {
            (ub + lb) / 2.0
        }
    }
}

// ---- the QP-problem abstraction -------------------------------------------

/// Complete description of one SMO-solvable quadratic program
///
/// ```text
///   min  ½ βᵀQβ + pᵀβ     s.t.  0 ≤ βᵢ ≤ C,   Σᵢ signsᵢ·βᵢ = const
/// ```
///
/// with `Q_ij = signsᵢ·signsⱼ·K(map[i], map[j])` over a kernel matrix on
/// the underlying dataset. The three LibSVM core formulations instantiate
/// it as:
///
/// | problem | variables | signs | p | map |
/// |---------|-----------|-------|---|-----|
/// | C-SVC | n | yᵢ | −1 | identity |
/// | ε-SVR | 2n (α then α*) | +1ⁿ, −1ⁿ | ε−zᵢ, ε+zᵢ | i mod n |
/// | one-class | n | +1ⁿ | 0 | identity |
///
/// The equality constraint's value is whatever the initial β satisfies
/// (0 for C-SVC/ε-SVR, ν·n for one-class) — the SMO pair updates preserve
/// it exactly, so the solver never needs to know it.
#[derive(Debug, Clone)]
pub struct QpSpec {
    /// Per-variable sign sᵢ ∈ {+1, −1} in the equality constraint and Q.
    pub signs: Vec<f64>,
    /// Linear term pᵢ of the objective (−1 for C-SVC).
    pub p: Vec<f64>,
    /// Box upper bound C, uniform over variables.
    pub c: f64,
    /// Variable → dataset-row map for kernel lookups (doubles rows for the
    /// ε-SVR α/α* pairs: variable i reads kernel row `map[i]`).
    pub map: Vec<usize>,
}

impl QpSpec {
    /// Number of QP variables (2n for ε-SVR, n otherwise).
    pub fn n_var(&self) -> usize {
        self.signs.len()
    }
}

/// One of the three LibSVM training objectives, expressed as a recipe for
/// building the [`QpSpec`] and the feasible cold-start point over a given
/// dataset. Implementations live in `smo::problem`
/// ([`SvcProblem`](super::SvcProblem), [`SvrProblem`](super::SvrProblem),
/// [`OneClassProblem`](super::OneClassProblem)).
pub trait QpProblem {
    /// Short name for tables and reports ("c_svc", "epsilon_svr", ...).
    fn name(&self) -> &'static str;

    /// Build the QP description over `ds`.
    fn spec(&self, ds: &Dataset) -> QpSpec;

    /// The feasible cold-start β (all-zero for C-SVC/ε-SVR; the LibSVM
    /// ν-fraction initial point for one-class, which fixes Σβ = ν·n).
    fn initial_alpha(&self, ds: &Dataset) -> Vec<f64>;
}

/// SMO solver over an arbitrary [`QpSpec`] — the engine behind the ε-SVR
/// and one-class paths. Runs the same second-order working-set selection
/// (WSS2), two-variable update **and LibSVM-style shrinking** as the
/// binary [`Solver`]: both paths drive the shared
/// [`ActiveSet`](super::ActiveSet) core (the constraint signs take the
/// role the labels play in the binary path), including the final
/// unshrink + full-KKT re-check before convergence is reported.
pub struct GeneralSolver {
    cache: KernelCache,
    spec: QpSpec,
    params: SmoParams,
}

impl GeneralSolver {
    /// Bind a solver to a kernel evaluator and a QP description. The
    /// kernel cache is sized by `params.cache_bytes`; `params.c` is
    /// ignored (the box bound comes from `spec.c`), while
    /// `params.shrinking` is honored exactly as in the binary path.
    pub fn new(eval: KernelEval, spec: QpSpec, params: SmoParams) -> GeneralSolver {
        assert_eq!(spec.signs.len(), spec.p.len(), "signs/p length mismatch");
        assert_eq!(spec.signs.len(), spec.map.len(), "signs/map length mismatch");
        let n_data = eval.len();
        assert!(
            spec.map.iter().all(|&d| d < n_data),
            "kernel map references a row outside the dataset"
        );
        let cache =
            KernelCache::with_byte_budget_dtype(eval, params.cache_bytes, params.cache_dtype);
        GeneralSolver {
            cache,
            spec,
            params,
        }
    }

    /// The QP description this solver optimises.
    pub fn spec(&self) -> &QpSpec {
        &self.spec
    }

    /// Number of QP variables.
    pub fn n_var(&self) -> usize {
        self.spec.n_var()
    }

    /// Solve from the all-zero start. (For one-class problems pass the
    /// [`QpProblem::initial_alpha`] point to [`GeneralSolver::solve_from`]
    /// instead — β = 0 does not satisfy Σβ = ν·n.)
    pub fn solve(&mut self) -> SmoResult {
        let m = self.n_var();
        self.solve_from(vec![0.0; m], None)
    }

    /// Solve from a feasible β (0 ≤ βᵢ ≤ C; the equality constraint's
    /// value is taken from β itself and preserved exactly). `initial_g`
    /// may carry a pre-computed gradient Gᵢ = Σⱼ βⱼQᵢⱼ + pᵢ.
    pub fn solve_from(&mut self, beta: Vec<f64>, initial_g: Option<Vec<f64>>) -> SmoResult {
        self.solve_seeded(beta, initial_g, None)
    }

    /// [`GeneralSolver::solve_from`] plus an optional carried active-set
    /// guess, with the same contract as [`Solver::solve_seeded`]:
    /// `inactive_seed` lists β positions (doubled α/α* positions for
    /// ε-SVR) believed bounded and non-violating; every proposed index is
    /// validated against the initial gradient before being shrunk, and
    /// the final unshrink + full-KKT re-check makes the converged model
    /// independent of the guess. Ignored when `params.shrinking` is off.
    pub fn solve_seeded(
        &mut self,
        beta: Vec<f64>,
        initial_g: Option<Vec<f64>>,
        inactive_seed: Option<&[usize]>,
    ) -> SmoResult {
        let m = self.n_var();
        assert_eq!(beta.len(), m);
        let c = self.spec.c;
        debug_assert!(
            beta.iter().all(|&b| (-1e-9..=c + 1e-9).contains(&b)),
            "initial beta violates box constraints"
        );

        let grad_start = Instant::now();
        let mut g = match initial_g {
            Some(g) => {
                assert_eq!(g.len(), m);
                g
            }
            None => self.compute_gradient(&beta),
        };
        let grad_init_secs = grad_start.elapsed().as_secs_f64();

        let mut beta = beta;
        let mut active = match inactive_seed {
            Some(guess) if self.params.shrinking && !guess.is_empty() => ActiveSet::seeded(
                m,
                &self.spec.signs,
                &beta,
                &g,
                c,
                self.params.eps,
                guess,
            ),
            _ => ActiveSet::full(m),
        };
        let mut iter: u64 = 0;
        let mut converged = false;

        loop {
            if iter >= self.params.max_iter {
                break;
            }

            // Periodic shrinking, same cadence and criterion as the
            // binary path (signs in place of labels).
            if self.params.shrinking && active.tick() {
                active.shrink(&self.spec.signs, &beta, &g, c, self.params.eps);
            }

            let (i, j) = match self.select_working_set(active.indices(), &beta, &g) {
                Some((i, j, _)) => (i, j),
                None => {
                    // Optimal on the active set: reconstruct the shrunk
                    // gradients and re-check the full problem before
                    // declaring convergence.
                    if !active.is_full() {
                        self.reconstruct_gradient_inactive(&beta, &mut g, active.indices());
                        active.unshrink();
                        match self.select_working_set(active.indices(), &beta, &g) {
                            Some(_) => continue,
                            None => {
                                converged = true;
                                break;
                            }
                        }
                    }
                    converged = true;
                    break;
                }
            };
            iter += 1;

            // Two-variable subproblem — the LibSVM update on Q-space
            // indices, with the data-row map applied at kernel lookups.
            let (si, sj) = (self.spec.signs[i], self.spec.signs[j]);
            let (di, dj) = (self.spec.map[i], self.spec.map[j]);
            let (kii, kjj) = (self.cache.value(di, di), self.cache.value(dj, dj));
            let kij = self.cache.value(di, dj);
            let mut quad = kii + kjj - 2.0 * kij;
            if quad <= 0.0 {
                quad = TAU;
            }

            let (old_bi, old_bj) = (beta[i], beta[j]);
            if si != sj {
                let delta = (-g[i] - g[j]) / quad;
                let diff = beta[i] - beta[j];
                beta[i] += delta;
                beta[j] += delta;
                if diff > 0.0 {
                    if beta[j] < 0.0 {
                        beta[j] = 0.0;
                        beta[i] = diff;
                    }
                } else if beta[i] < 0.0 {
                    beta[i] = 0.0;
                    beta[j] = -diff;
                }
                if diff > 0.0 {
                    if beta[i] > c {
                        beta[i] = c;
                        beta[j] = c - diff;
                    }
                } else if beta[j] > c {
                    beta[j] = c;
                    beta[i] = c + diff;
                }
            } else {
                let delta = (g[i] - g[j]) / quad;
                let sum = beta[i] + beta[j];
                beta[i] -= delta;
                beta[j] += delta;
                if sum > c {
                    if beta[i] > c {
                        beta[i] = c;
                        beta[j] = sum - c;
                    }
                } else if beta[j] < 0.0 {
                    beta[j] = 0.0;
                    beta[i] = sum;
                }
                if sum > c {
                    if beta[j] > c {
                        beta[j] = c;
                        beta[i] = sum - c;
                    }
                } else if beta[i] < 0.0 {
                    beta[i] = 0.0;
                    beta[j] = sum;
                }
            }

            // Gradient update: G_t += Q_ti·Δβᵢ + Q_tj·Δβⱼ with
            // Q_ti = s_t·sᵢ·K(map[t], map[i]).
            let dbi = beta[i] - old_bi;
            let dbj = beta[j] - old_bj;
            if dbi != 0.0 || dbj != 0.0 {
                let ci = si * dbi;
                let cj = sj * dbj;
                let (row_i, row_j) = self.cache.row_pair(di, dj);
                // Hoisted dtype match: the f64 tier keeps the historical
                // arithmetic bit-for-bit.
                match (row_i.as_f64(), row_j.as_f64()) {
                    (Some(ri), Some(rj)) => {
                        for &t in active.indices() {
                            let dt = self.spec.map[t];
                            g[t] += self.spec.signs[t] * (ci * ri[dt] + cj * rj[dt]);
                        }
                    }
                    _ => {
                        for &t in active.indices() {
                            let dt = self.spec.map[t];
                            g[t] +=
                                self.spec.signs[t] * (ci * row_i.get(dt) + cj * row_j.get(dt));
                        }
                    }
                }
            }
        }

        // g may be stale for shrunk indices if we stopped at max_iter.
        if !active.is_full() {
            self.reconstruct_gradient_inactive(&beta, &mut g, active.indices());
        }

        let b = self.compute_bias(&beta, &g);

        // Dual objective ½·Σᵢ βᵢ(Gᵢ + pᵢ)  (since G = Qβ + p).
        let objective = 0.5
            * beta
                .iter()
                .zip(&g)
                .zip(&self.spec.p)
                .map(|((&bv, &gv), &pv)| bv * (gv + pv))
                .sum::<f64>();

        let n_sv = beta.iter().filter(|&&b| b > 0.0).count();
        let n_bsv = beta.iter().filter(|&&b| b >= c).count();
        let partition = partition_of(&beta, c);

        SmoResult {
            alpha: beta,
            b,
            iterations: iter,
            objective,
            n_sv,
            n_bsv,
            converged,
            grad_init_secs,
            g,
            partition,
            shrink_passes: active.passes(),
        }
    }

    /// Recompute G for every variable outside `active` from scratch —
    /// the general-path unshrink reconstruction, sharing the core with
    /// the binary solver (p from the spec, signs in place of labels,
    /// kernel rows through the variable → data-row map).
    fn reconstruct_gradient_inactive(&mut self, beta: &[f64], g: &mut [f64], active: &[usize]) {
        let cache = &mut self.cache;
        let spec = &self.spec;
        reconstruct_inactive(
            g,
            active,
            |t| spec.p[t],
            &spec.signs,
            beta,
            |t| spec.map[t],
            |j| cache.row_arc(spec.map[j]),
        );
    }

    /// Gᵢ = Σⱼ βⱼQᵢⱼ + pᵢ from the non-zero variables. Sequential — the
    /// general path leaves the parallel blocked sweep to the binary
    /// solver, whose cache layout it would otherwise duplicate.
    pub fn compute_gradient(&mut self, beta: &[f64]) -> Vec<f64> {
        let m = self.n_var();
        assert_eq!(beta.len(), m);
        let mut g = self.spec.p.clone();
        for j in 0..m {
            if beta[j] > 0.0 {
                let coef = beta[j] * self.spec.signs[j];
                let dj = self.spec.map[j];
                let row = self.cache.row_arc(dj);
                match row.as_f64() {
                    Some(r) => {
                        for t in 0..m {
                            g[t] += self.spec.signs[t] * coef * r[self.spec.map[t]];
                        }
                    }
                    None => {
                        for t in 0..m {
                            g[t] += self.spec.signs[t] * coef * row.get(self.spec.map[t]);
                        }
                    }
                }
            }
        }
        g
    }

    /// WSS2 over the active variable set; `None` when ε-optimal on it.
    fn select_working_set(
        &mut self,
        active: &[usize],
        beta: &[f64],
        g: &[f64],
    ) -> Option<(usize, usize, f64)> {
        let c = self.spec.c;

        // i = argmax_{t ∈ I_up} −s_t·G_t
        let mut gmax = f64::NEG_INFINITY;
        let mut i = usize::MAX;
        for &t in active {
            let s = self.spec.signs[t];
            let in_up = (s > 0.0 && beta[t] < c) || (s < 0.0 && beta[t] > 0.0);
            if in_up {
                let v = -s * g[t];
                if v >= gmax {
                    gmax = v;
                    i = t;
                }
            }
        }
        if i == usize::MAX {
            return None;
        }

        let di = self.spec.map[i];
        // The scan pins row i as an owned refcounted row (replacing an
        // `unsafe` raw-slice borrow), so `diag` below may touch the cache
        // freely.
        let row_i = self.cache.row_arc(di);
        let kii = row_i.get(di);

        let mut gmin = f64::INFINITY;
        let mut obj_min = f64::INFINITY;
        let mut j = usize::MAX;
        for &t in active {
            let s = self.spec.signs[t];
            let in_low = (s > 0.0 && beta[t] > 0.0) || (s < 0.0 && beta[t] < c);
            if !in_low {
                continue;
            }
            let v = -s * g[t];
            if v < gmin {
                gmin = v;
            }
            let b_it = gmax - v;
            if b_it > 0.0 {
                // Curvature along the SMO pair direction (Δβᵢ = sᵢ·η,
                // Δβ_t = −s_t·η): dᵀQd/η² = K_ii + K_tt − 2·K_it — raw
                // kernel values, the signs cancel. This matches the
                // update step's `quad` exactly (LibSVM's quad_coef); an
                // ε-SVR (αᵢ, α*ᵢ) pair is a flat direction (a = 0 → TAU).
                let ktt = self.diag(t);
                let mut a_it = kii + ktt - 2.0 * row_i.get(self.spec.map[t]);
                if a_it <= 0.0 {
                    a_it = TAU;
                }
                let dec = -(b_it * b_it) / a_it;
                if dec <= obj_min {
                    obj_min = dec;
                    j = t;
                }
            }
        }

        if gmax - gmin < self.params.eps || j == usize::MAX {
            return None;
        }
        Some((i, j, gmax - gmin))
    }

    /// K(map[t], map[t]); O(1) for RBF (=1), computed otherwise.
    #[inline]
    fn diag(&mut self, t: usize) -> f64 {
        match self.cache.kernel() {
            crate::kernel::Kernel::Rbf { .. } => 1.0,
            _ => {
                let dt = self.spec.map[t];
                self.cache.value(dt, dt)
            }
        }
    }

    /// ρ from the final gradient — the same free-variable average as the
    /// binary path, over the problem's signs.
    fn compute_bias(&self, beta: &[f64], g: &[f64]) -> f64 {
        let c = self.spec.c;
        let mut free_sum = 0.0;
        let mut free_count = 0usize;
        let (mut ub, mut lb) = (f64::INFINITY, f64::NEG_INFINITY);
        for t in 0..beta.len() {
            let s = self.spec.signs[t];
            let sg = s * g[t];
            if beta[t] > 0.0 && beta[t] < c {
                free_sum += sg;
                free_count += 1;
            } else {
                let in_up = (s > 0.0 && beta[t] <= 0.0) || (s < 0.0 && beta[t] >= c);
                if in_up {
                    ub = ub.min(sg);
                } else {
                    lb = lb.max(sg);
                }
            }
        }
        if free_count > 0 {
            free_sum / free_count as f64
        } else {
            (ub + lb) / 2.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DataMatrix, Dataset};
    use crate::kernel::Kernel;
    use crate::smo::verify::kkt_violation;

    fn solve_ds(ds: Dataset, kernel: Kernel, c: f64) -> (SmoResult, KernelEval) {
        let eval = KernelEval::new(ds, kernel);
        let mut solver = Solver::new(eval.clone(), SmoParams::with_c(c));
        (solver.solve(), eval)
    }

    /// Two separable points: analytic solution known.
    #[test]
    fn two_point_linear_analytic() {
        // x = −1 (y=−1), x = +1 (y=+1), linear kernel.
        // Dual: max 2α − ½αᵀQα with α₁=α₂=α (equality constraint), Q=[[1,-1],[-1,1]]·yy→
        // obj = 2α − ½(α²·(1) + α²(1) − 2α²(−1·1·−1)) ... direct known result: α = 0.5, w = 1, b = 0.
        let ds = Dataset::new(
            "2pt",
            DataMatrix::dense(2, 1, vec![-1.0, 1.0]),
            vec![-1.0, 1.0],
        );
        let (r, _) = solve_ds(ds, Kernel::Linear, 10.0);
        assert!(r.converged);
        assert!((r.alpha[0] - 0.5).abs() < 1e-3, "alpha {:?}", r.alpha);
        assert!((r.alpha[1] - 0.5).abs() < 1e-3);
        assert!(r.b.abs() < 1e-3, "b = {}", r.b);
    }

    /// Four-point XOR with RBF: must be separable (classic sanity check).
    #[test]
    fn xor_rbf_separates() {
        let ds = Dataset::new(
            "xor",
            DataMatrix::dense(4, 2, vec![0., 0., 1., 1., 0., 1., 1., 0.]),
            vec![1.0, 1.0, -1.0, -1.0],
        );
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(1.0));
        let mut solver = Solver::new(eval.clone(), SmoParams::with_c(100.0));
        let r = solver.solve();
        assert!(r.converged);
        // All four points should be correctly classified.
        for i in 0..4 {
            let dec: f64 = (0..4)
                .map(|j| ds.y[j] * r.alpha[j] * eval.eval(j, i))
                .sum::<f64>()
                - r.b;
            assert!(dec * ds.y[i] > 0.0, "point {i} misclassified: {dec}");
        }
    }

    #[test]
    fn kkt_satisfied_at_convergence() {
        let ds = crate::data::synth::generate("heart", Some(80), 3);
        let eval = KernelEval::new(ds, Kernel::rbf(0.2));
        let mut solver = Solver::new(eval.clone(), SmoParams::with_c(10.0));
        let r = solver.solve();
        assert!(r.converged);
        let report = kkt_violation(&eval, &r.alpha, 10.0);
        assert!(
            report.max_violation < 2e-3,
            "KKT violation {}",
            report.max_violation
        );
        // equality constraint holds
        assert!(report.sum_y_alpha.abs() < 1e-8);
    }

    #[test]
    fn warm_start_from_optimum_is_instant() {
        let ds = crate::data::synth::generate("heart", Some(60), 5);
        let eval = KernelEval::new(ds, Kernel::rbf(0.2));
        let mut s1 = Solver::new(eval.clone(), SmoParams::with_c(5.0));
        let r1 = s1.solve();
        assert!(r1.converged);
        // Re-solve seeded with the optimum: should take (near-)zero iterations.
        let mut s2 = Solver::new(eval, SmoParams::with_c(5.0));
        let r2 = s2.solve_from(r1.alpha.clone(), None);
        assert!(r2.converged);
        assert!(
            r2.iterations <= 2,
            "seeding with the optimum still took {} iterations",
            r2.iterations
        );
        assert!((r2.objective - r1.objective).abs() < 1e-6);
    }

    #[test]
    fn seeded_and_cold_agree() {
        let ds = crate::data::synth::generate("heart", Some(80), 7);
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(0.2));
        let mut cold = Solver::new(eval.clone(), SmoParams::with_c(2.0));
        let rc = cold.solve();

        // a feasible (but arbitrary) warm start: balanced small values
        let n = ds.len();
        let mut alpha = vec![0.0; n];
        let pos: Vec<usize> = (0..n).filter(|&i| ds.y[i] > 0.0).collect();
        let neg: Vec<usize> = (0..n).filter(|&i| ds.y[i] < 0.0).collect();
        let m = pos.len().min(neg.len());
        for t in 0..m {
            alpha[pos[t]] = 0.5;
            alpha[neg[t]] = 0.5;
        }
        let mut warm = Solver::new(eval, SmoParams::with_c(2.0));
        let rw = warm.solve_from(alpha, None);
        assert!(rw.converged);
        assert!(
            (rw.objective - rc.objective).abs() < 1e-3 * rc.objective.abs().max(1.0),
            "objectives differ: cold {} vs warm {}",
            rc.objective,
            rw.objective
        );
        assert!((rw.b - rc.b).abs() < 5e-3, "bias differ {} vs {}", rw.b, rc.b);
    }

    #[test]
    fn shrinking_matches_no_shrinking() {
        let ds = crate::data::synth::generate("adult", Some(150), 11);
        let eval = KernelEval::new(ds, Kernel::rbf(0.5));
        let mut with = Solver::new(
            eval.clone(),
            SmoParams {
                c: 100.0,
                shrinking: true,
                ..Default::default()
            },
        );
        let mut without = Solver::new(
            eval,
            SmoParams {
                c: 100.0,
                shrinking: false,
                ..Default::default()
            },
        );
        let rs = with.solve();
        let rn = without.solve();
        assert!(rs.converged && rn.converged);
        assert!(
            (rs.objective - rn.objective).abs() < 1e-2 * rn.objective.abs().max(1.0),
            "obj: shrink {} vs none {}",
            rs.objective,
            rn.objective
        );
    }

    #[test]
    fn all_bounded_madelon_regime() {
        // Random labels at small C: every α goes to the bound C.
        let ds = crate::data::synth::generate("madelon", Some(60), 13);
        let eval = KernelEval::new(ds, Kernel::rbf(std::f64::consts::FRAC_1_SQRT_2));
        let mut solver = Solver::new(eval, SmoParams::with_c(1.0));
        let r = solver.solve();
        assert!(r.converged);
        let frac_sv = r.n_sv as f64 / r.alpha.len() as f64;
        assert!(frac_sv > 0.9, "madelon regime should make ~all SVs: {frac_sv}");
    }

    #[test]
    fn parallel_gradient_init_is_bit_identical() {
        // n ≥ PAR_MIN_N so the parallel path actually engages; seed from a
        // solved model so the warm-start gradient has real work to do.
        let ds = crate::data::synth::generate("heart", Some(300), 9);
        let eval = KernelEval::new(ds, Kernel::rbf(0.2));
        let mut first = Solver::new(eval.clone(), SmoParams::with_c(5.0));
        let r0 = first.solve();
        assert!(r0.converged);

        let solve_with = |threads: usize| {
            let mut s = Solver::new(
                eval.clone(),
                SmoParams {
                    c: 5.0,
                    threads,
                    ..Default::default()
                },
            );
            s.solve_from(r0.alpha.clone(), None)
        };
        let seq = solve_with(1);
        for threads in [2usize, 8] {
            let par = solve_with(threads);
            assert_eq!(seq.iterations, par.iterations, "threads={threads}");
            assert_eq!(seq.b.to_bits(), par.b.to_bits(), "threads={threads}");
            for (a, b) in seq.alpha.iter().zip(&par.alpha) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
            for (a, b) in seq.g.iter().zip(&par.g) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn max_iter_cap_respected() {
        let ds = crate::data::synth::generate("heart", Some(100), 17);
        let eval = KernelEval::new(ds, Kernel::rbf(0.2));
        let mut solver = Solver::new(
            eval,
            SmoParams {
                c: 1000.0,
                max_iter: 5,
                ..Default::default()
            },
        );
        let r = solver.solve();
        assert_eq!(r.iterations, 5);
        assert!(!r.converged);
    }
}
