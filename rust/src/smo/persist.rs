//! Model persistence in LibSVM's `svm_save_model` text format.
//!
//! A model trained here loads in stock LibSVM tooling and vice versa
//! (binary C-SVC with the four classic kernels). Format:
//!
//! ```text
//! svm_type c_svc
//! kernel_type rbf
//! gamma 0.5
//! nr_class 2
//! total_sv 3
//! rho 0.25
//! label 1 -1
//! nr_sv 2 1
//! SV
//! 0.5 1:0.1 3:0.2
//! ...
//! ```

use super::model::Model;
use crate::data::{CsrMatrix, DataMatrix, Dataset};
use crate::kernel::Kernel;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Errors from saving or loading a LibSVM-format model file.
#[derive(Debug)]
pub enum ModelIoError {
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Malformed model file.
    Parse {
        /// 1-based line number.
        line: usize,
        /// What was wrong with it.
        msg: String,
    },
    /// Valid LibSVM model of a kind this crate does not load.
    Unsupported(String),
}

impl std::fmt::Display for ModelIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelIoError::Io(e) => write!(f, "io: {e}"),
            ModelIoError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            ModelIoError::Unsupported(what) => write!(f, "unsupported model: {what}"),
        }
    }
}

impl std::error::Error for ModelIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ModelIoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ModelIoError {
    fn from(e: std::io::Error) -> ModelIoError {
        ModelIoError::Io(e)
    }
}

impl Model {
    /// Serialise in LibSVM model format. Support vectors are written with
    /// positive-label SVs first (LibSVM's class-grouped layout).
    pub fn save(&self, mut w: impl Write) -> Result<(), ModelIoError> {
        writeln!(w, "svm_type c_svc")?;
        match self.kernel {
            Kernel::Rbf { gamma } => {
                writeln!(w, "kernel_type rbf")?;
                writeln!(w, "gamma {gamma}")?;
            }
            Kernel::Linear => writeln!(w, "kernel_type linear")?,
            Kernel::Poly {
                gamma,
                coef0,
                degree,
            } => {
                writeln!(w, "kernel_type polynomial")?;
                writeln!(w, "degree {degree}")?;
                writeln!(w, "gamma {gamma}")?;
                writeln!(w, "coef0 {coef0}")?;
            }
            Kernel::Sigmoid { gamma, coef0 } => {
                writeln!(w, "kernel_type sigmoid")?;
                writeln!(w, "gamma {gamma}")?;
                writeln!(w, "coef0 {coef0}")?;
            }
        }
        writeln!(w, "nr_class 2")?;
        writeln!(w, "total_sv {}", self.n_sv())?;
        writeln!(w, "rho {}", self.b)?;
        writeln!(w, "label 1 -1")?;
        let pos: Vec<usize> = (0..self.n_sv()).filter(|&i| self.sv.y[i] > 0.0).collect();
        let neg: Vec<usize> = (0..self.n_sv()).filter(|&i| self.sv.y[i] < 0.0).collect();
        writeln!(w, "nr_sv {} {}", pos.len(), neg.len())?;
        writeln!(w, "SV")?;
        for &i in pos.iter().chain(neg.iter()) {
            // sv_coef = y_i * alpha_i = coef[i]
            write!(w, "{}", self.coef[i])?;
            match &self.sv.x {
                DataMatrix::Sparse(m) => {
                    let (idx, val) = m.row(i);
                    for (&c, &v) in idx.iter().zip(val) {
                        write!(w, " {}:{}", c + 1, v)?;
                    }
                }
                DataMatrix::Dense { .. } => {
                    for (j, &v) in self.sv.x.dense_row(i).iter().enumerate() {
                        if v != 0.0 {
                            write!(w, " {}:{}", j + 1, v)?;
                        }
                    }
                }
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Save to a file path.
    pub fn save_file(&self, path: impl AsRef<Path>) -> Result<(), ModelIoError> {
        let f = std::fs::File::create(path)?;
        self.save(std::io::BufWriter::new(f))
    }

    /// Parse a LibSVM model (binary c_svc only — the paper's setting).
    pub fn load(r: impl std::io::Read) -> Result<Model, ModelIoError> {
        let reader = BufReader::new(r);
        let mut lines = reader.lines().enumerate();

        let mut kernel_type = String::new();
        let mut gamma = 0.0f64;
        let mut coef0 = 0.0f64;
        let mut degree = 3u32;
        let mut rho = 0.0f64;
        let mut nr_sv: Vec<usize> = Vec::new();
        let mut labels: Vec<f64> = Vec::new();

        // header
        loop {
            let (lineno, line) = lines
                .next()
                .ok_or_else(|| ModelIoError::Parse {
                    line: 0,
                    msg: "missing SV section".into(),
                })?;
            let line = line?;
            let mut parts = line.split_ascii_whitespace();
            let key = parts.next().unwrap_or("");
            let err = |msg: &str| ModelIoError::Parse {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            match key {
                "svm_type" => {
                    let v = parts.next().ok_or_else(|| err("missing svm_type"))?;
                    if v != "c_svc" {
                        return Err(ModelIoError::Unsupported(format!("svm_type {v}")));
                    }
                }
                "kernel_type" => {
                    kernel_type = parts.next().ok_or_else(|| err("missing kernel"))?.to_string()
                }
                "gamma" => gamma = parse_f64(parts.next(), lineno)?,
                "coef0" => coef0 = parse_f64(parts.next(), lineno)?,
                "degree" => degree = parse_f64(parts.next(), lineno)? as u32,
                "rho" => rho = parse_f64(parts.next(), lineno)?,
                "nr_class" => {
                    let n = parse_f64(parts.next(), lineno)? as usize;
                    if n != 2 {
                        return Err(ModelIoError::Unsupported(format!("nr_class {n}")));
                    }
                }
                "total_sv" => {}
                "label" => {
                    labels = parts
                        .map(|p| p.parse::<f64>())
                        .collect::<Result<_, _>>()
                        .map_err(|_| err("bad label list"))?;
                }
                "nr_sv" => {
                    nr_sv = parts
                        .map(|p| p.parse::<usize>())
                        .collect::<Result<_, _>>()
                        .map_err(|_| err("bad nr_sv list"))?;
                }
                "SV" => break,
                other => {
                    return Err(ModelIoError::Unsupported(format!("header key '{other}'")))
                }
            }
        }
        if labels.len() != 2 || nr_sv.len() != 2 {
            return Err(ModelIoError::Unsupported(
                "model must be binary (2 labels)".into(),
            ));
        }

        let kernel = match kernel_type.as_str() {
            "rbf" => Kernel::Rbf { gamma },
            "linear" => Kernel::Linear,
            "polynomial" => Kernel::Poly {
                gamma,
                coef0,
                degree,
            },
            "sigmoid" => Kernel::Sigmoid { gamma, coef0 },
            other => return Err(ModelIoError::Unsupported(format!("kernel '{other}'"))),
        };

        // SV rows
        let mut coef = Vec::new();
        let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
        let mut max_col = 0u32;
        for (lineno, line) in lines {
            let line = line?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_ascii_whitespace();
            let c: f64 = parts
                .next()
                .unwrap()
                .parse()
                .map_err(|_| ModelIoError::Parse {
                    line: lineno + 1,
                    msg: "bad sv_coef".into(),
                })?;
            let mut row = Vec::new();
            for tok in parts {
                let (i, v) = tok.split_once(':').ok_or_else(|| ModelIoError::Parse {
                    line: lineno + 1,
                    msg: format!("bad feature {tok:?}"),
                })?;
                let idx: u32 = i.parse().map_err(|_| ModelIoError::Parse {
                    line: lineno + 1,
                    msg: "bad index".into(),
                })?;
                let val: f32 = v.parse().map_err(|_| ModelIoError::Parse {
                    line: lineno + 1,
                    msg: "bad value".into(),
                })?;
                max_col = max_col.max(idx - 1);
                row.push((idx - 1, val));
            }
            row.sort_by_key(|&(c, _)| c);
            rows.push(row);
            coef.push(c);
        }
        if rows.len() != nr_sv[0] + nr_sv[1] {
            return Err(ModelIoError::Parse {
                line: 0,
                msg: format!(
                    "SV count {} != nr_sv sum {}",
                    rows.len(),
                    nr_sv[0] + nr_sv[1]
                ),
            });
        }
        // labels per class-grouped layout
        let y: Vec<f64> = (0..rows.len())
            .map(|i| if i < nr_sv[0] { labels[0] } else { labels[1] })
            .collect();
        let csr = CsrMatrix::from_rows(max_col as usize + 1, &rows);
        let sv = Dataset::new("loaded-model", DataMatrix::Sparse(csr), y);
        Ok(Model {
            sv,
            coef,
            b: rho,
            kernel,
        })
    }

    /// Load from a file path.
    pub fn load_file(path: impl AsRef<Path>) -> Result<Model, ModelIoError> {
        let f = std::fs::File::open(path)?;
        Model::load(f)
    }
}

fn parse_f64(tok: Option<&str>, lineno: usize) -> Result<f64, ModelIoError> {
    tok.and_then(|t| t.parse().ok()).ok_or(ModelIoError::Parse {
        line: lineno + 1,
        msg: "bad number".into(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelEval;
    use crate::smo::{SmoParams, Solver};

    fn trained() -> (Dataset, Model) {
        let ds = crate::data::synth::generate("heart", Some(60), 3);
        let kernel = Kernel::rbf(0.2);
        let mut solver = Solver::new(KernelEval::new(ds.clone(), kernel), SmoParams::with_c(2.0));
        let r = solver.solve();
        (ds.clone(), Model::from_result(&ds, kernel, &r))
    }

    #[test]
    fn roundtrip_preserves_decisions() {
        let (ds, model) = trained();
        let mut buf = Vec::new();
        model.save(&mut buf).unwrap();
        let loaded = Model::load(&buf[..]).unwrap();
        assert_eq!(loaded.n_sv(), model.n_sv());
        assert!((loaded.b - model.b).abs() < 1e-12);
        // identical predictions on the training set
        let d0 = model.decision_values(&ds);
        let d1 = loaded.decision_values(&ds);
        for (a, b) in d0.iter().zip(&d1) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn header_is_libsvm_shaped() {
        let (_, model) = trained();
        let mut buf = Vec::new();
        model.save(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("svm_type c_svc\nkernel_type rbf\n"));
        assert!(text.contains("\nrho "));
        assert!(text.contains("\nlabel 1 -1\n"));
        assert!(text.contains("\nSV\n"));
        // positive-class SVs first: their coefs are positive
        let sv_section = text.split("\nSV\n").nth(1).unwrap();
        let first_coef: f64 = sv_section
            .lines()
            .next()
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(first_coef > 0.0);
    }

    #[test]
    fn rejects_unsupported() {
        assert!(matches!(
            Model::load("svm_type nu_svc\n".as_bytes()),
            Err(ModelIoError::Unsupported(_))
        ));
        assert!(Model::load("garbage header\n".as_bytes()).is_err());
    }

    #[test]
    fn save_load_file_paths() {
        let (_, model) = trained();
        let path = std::env::temp_dir().join("alphaseed_model_test.svm");
        model.save_file(&path).unwrap();
        let loaded = Model::load_file(&path).unwrap();
        assert_eq!(loaded.n_sv(), model.n_sv());
        let _ = std::fs::remove_file(path);
    }
}
