//! The shared active-set (shrinking) core of the SMO solver family.
//!
//! LibSVM's shrinking heuristic removes variables that sit at a box bound
//! and satisfy their KKT condition with margin from the working set, so
//! the per-iteration working-set selection and gradient update scan only
//! the *active* variables. The three QP formulations this crate solves —
//! binary C-SVC ([`Solver`](super::Solver)), and ε-SVR / one-class through
//! the [`GeneralSolver`](super::GeneralSolver) — share the exact same
//! criterion once the C-SVC label yᵢ is read as the general per-variable
//! constraint sign sᵢ, so the machinery lives here once:
//!
//! - [`ActiveSet`] — the membership list plus the shrink cadence
//!   (one pass every `min(n, 1000)` iterations, LibSVM's schedule);
//! - the `be_shrunk` criterion (private) — LibSVM's rule verbatim with
//!   s ↔ y. Extracting it also fixed a latent sign error in the old
//!   binary-only implementation: for s = −1 variables the old code
//!   compared the raw gradient instead of its negation, which could
//!   shrink *violating* variables (correctness was rescued by the final
//!   unshrink + re-check, but every such mistake cost an extra
//!   reconstruct-and-resume cycle);
//! - [`reconstruct_inactive`] (crate-private) — recompute the gradient of
//!   every shrunk variable from scratch on unshrink;
//! - [`ActiveSet::seeded`] — the **cross-fold carry-over** entry point:
//!   a caller-proposed initially-inactive set (e.g. the previous fold's
//!   bounded variables mapped through a [`Seeder`](crate::seeding::Seeder))
//!   is validated variable-by-variable against the *current* gradient
//!   before any of it is trusted, so a wrong guess can only cost time,
//!   never correctness.
//!
//! Correctness contract (all three formulations): whenever the active set
//! looks ε-optimal the solver reconstructs the gradient of every shrunk
//! variable, restores the full set and re-checks; it only reports
//! convergence when the **full** problem satisfies the ε-KKT condition.
//! The converged model is therefore the same ε-KKT point the unshrunken
//! path reaches (to solver tolerance — the two paths accumulate floating
//! point in different orders, so bit-equality is only guaranteed when a
//! proposed seed is rejected outright; `tests/shrink_identity.rs` pins
//! both statements).

use crate::kernel::KernelRow;

/// Position of one dual variable relative to its box `[0, C]` — the
/// terminal partition [`SmoResult`](super::SmoResult) exports so the next
/// cross-validation round can carry the solver's active-set knowledge
/// forward (the paper's SV-identification argument, applied to the
/// solver's internal state instead of the α values).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarBound {
    /// At the lower bound: α = 0 (not a support vector).
    Lower,
    /// Strictly inside the box: 0 < α < C (a free / margin SV).
    Free,
    /// At the upper bound: α = C (a bounded SV).
    Upper,
}

/// Classify every variable of a solved α against the box `[0, c]`.
/// The SMO two-variable update writes exact `0.0` / `c` at the clips, so
/// the comparison is exact, not a tolerance test.
pub fn partition_of(alpha: &[f64], c: f64) -> Vec<VarBound> {
    alpha
        .iter()
        .map(|&a| {
            if a >= c {
                VarBound::Upper
            } else if a <= 0.0 {
                VarBound::Lower
            } else {
                VarBound::Free
            }
        })
        .collect()
}

/// LibSVM `be_shrunk` with the general constraint sign s in place of the
/// C-SVC label y: a *bounded* variable is shrinkable when it is strictly
/// non-violating against the current maximal-violation brackets
/// (`gmax1` = max over I_up of −s·G, `gmax2` = max over I_low of s·G).
#[inline]
fn be_shrunk(s: f64, a: f64, g: f64, c: f64, gmax1: f64, gmax2: f64) -> bool {
    if a >= c {
        // upper bound
        if s > 0.0 {
            -g > gmax1
        } else {
            -g > gmax2
        }
    } else if a <= 0.0 {
        // lower bound
        if s > 0.0 {
            g > gmax2
        } else {
            g > gmax1
        }
    } else {
        false
    }
}

/// The maximal-violation brackets over `idx`:
/// `gmax1 = max_{t ∈ I_up} −s_t·G_t`, `gmax2 = max_{t ∈ I_low} s_t·G_t`.
/// Their sum is the current KKT violation (LibSVM's stopping quantity).
fn violation_bounds(
    idx: impl Iterator<Item = usize>,
    signs: &[f64],
    alpha: &[f64],
    g: &[f64],
    c: f64,
) -> (f64, f64) {
    let (mut gmax1, mut gmax2) = (f64::NEG_INFINITY, f64::NEG_INFINITY);
    for t in idx {
        let (s, a) = (signs[t], alpha[t]);
        if (s > 0.0 && a < c) || (s < 0.0 && a > 0.0) {
            gmax1 = gmax1.max(-s * g[t]);
        }
        if (s > 0.0 && a > 0.0) || (s < 0.0 && a < c) {
            gmax2 = gmax2.max(s * g[t]);
        }
    }
    (gmax1, gmax2)
}

/// The LibSVM shrinking state machine shared by the binary and general
/// solvers: the active index list, the shrink cadence counter, and the
/// has-shrunk flag that gates the final unshrink-and-re-check.
#[derive(Debug, Clone)]
pub struct ActiveSet {
    idx: Vec<usize>,
    n: usize,
    shrunk: bool,
    interval: u64,
    counter: u64,
    passes: u64,
}

impl ActiveSet {
    /// Start with every variable active (the cold active state).
    pub fn full(n: usize) -> ActiveSet {
        let interval = n.clamp(1, 1000) as u64;
        ActiveSet {
            idx: (0..n).collect(),
            n,
            shrunk: false,
            interval,
            counter: interval,
            passes: 0,
        }
    }

    /// Start from a carried-over guess: `inactive_guess` holds variable
    /// indices the caller believes are bounded and non-violating (e.g. the
    /// previous CV round's bounded partition mapped onto this round's
    /// layout). Every proposed index is **validated against the current
    /// gradient** — only variables that are bounded at `alpha` *and* pass
    /// the LibSVM shrink criterion right now are actually removed, so a
    /// wrong guess degrades to the full active set instead of corrupting
    /// the solve. Near the optimum (violation ≤ 10·eps, LibSVM's
    /// unshrink threshold) the guess is ignored entirely.
    #[allow(clippy::too_many_arguments)]
    pub fn seeded(
        n: usize,
        signs: &[f64],
        alpha: &[f64],
        g: &[f64],
        c: f64,
        eps: f64,
        inactive_guess: &[usize],
    ) -> ActiveSet {
        let mut set = ActiveSet::full(n);
        let (gmax1, gmax2) = violation_bounds(0..n, signs, alpha, g, c);
        if !(gmax1 + gmax2).is_finite() || gmax1 + gmax2 <= eps * 10.0 {
            return set;
        }
        let mut drop = vec![false; n];
        for &t in inactive_guess {
            if t < n && be_shrunk(signs[t], alpha[t], g[t], c, gmax1, gmax2) {
                drop[t] = true;
            }
        }
        set.idx.retain(|&t| !drop[t]);
        if set.idx.len() < n {
            set.shrunk = true;
            set.passes = 1;
        }
        set
    }

    /// The active variable indices, ascending.
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Whether every variable is currently active.
    pub fn is_full(&self) -> bool {
        self.idx.len() == self.n
    }

    /// Number of shrink passes run so far (periodic scans plus a seeded
    /// initialisation that removed variables) — a cheap observability
    /// counter for tests and reports.
    pub fn passes(&self) -> u64 {
        self.passes
    }

    /// Advance the shrink cadence by one iteration; `true` when a shrink
    /// pass is due (every `min(n, 1000)` iterations, LibSVM's schedule).
    pub fn tick(&mut self) -> bool {
        self.counter -= 1;
        if self.counter == 0 {
            self.counter = self.interval;
            true
        } else {
            false
        }
    }

    /// One shrink pass: drop every bounded, strictly non-violating active
    /// variable. Near the optimum (violation ≤ 10·eps) the pass is a
    /// no-op, matching LibSVM's guard against shrinking variables the
    /// final convergence check is about to need.
    pub fn shrink(&mut self, signs: &[f64], alpha: &[f64], g: &[f64], c: f64, eps: f64) {
        self.passes += 1;
        let (gmax1, gmax2) = violation_bounds(self.idx.iter().copied(), signs, alpha, g, c);
        if gmax1 + gmax2 <= eps * 10.0 {
            return;
        }
        let before = self.idx.len();
        self.idx
            .retain(|&t| !be_shrunk(signs[t], alpha[t], g[t], c, gmax1, gmax2));
        if self.idx.len() < before {
            self.shrunk = true;
        }
    }

    /// Restore the full active set and restart the cadence. The caller
    /// must reconstruct the gradient of the previously inactive variables
    /// *before* relying on it (see [`reconstruct_inactive`]).
    pub fn unshrink(&mut self) {
        self.idx = (0..self.n).collect();
        self.shrunk = false;
        self.counter = self.interval;
    }
}

/// Recompute `g[t] = Σⱼ αⱼ·Q_tj + p_t` from scratch for every variable
/// outside `active` — the unshrink gradient reconstruction shared by both
/// solvers. `linear` supplies p_t (−1 for C-SVC), `map` the
/// variable → kernel-row column (identity except for ε-SVR's doubled
/// variables) and `row` fetches the cached kernel row of variable `j`'s
/// data instance. Only inactive entries of `g` are touched.
pub(crate) fn reconstruct_inactive(
    g: &mut [f64],
    active: &[usize],
    linear: impl Fn(usize) -> f64,
    signs: &[f64],
    alpha: &[f64],
    map: impl Fn(usize) -> usize,
    mut row: impl FnMut(usize) -> KernelRow,
) {
    let n = g.len();
    let mut is_active = vec![false; n];
    for &t in active {
        is_active[t] = true;
    }
    if active.len() == n {
        return;
    }
    for (t, slot) in g.iter_mut().enumerate() {
        if !is_active[t] {
            *slot = linear(t);
        }
    }
    for j in 0..n {
        if alpha[j] > 0.0 {
            let coef = alpha[j] * signs[j];
            let r = row(j);
            match r.as_f64() {
                Some(rf) => {
                    for (t, slot) in g.iter_mut().enumerate() {
                        if !is_active[t] {
                            *slot += signs[t] * coef * rf[map(t)];
                        }
                    }
                }
                None => {
                    for (t, slot) in g.iter_mut().enumerate() {
                        if !is_active[t] {
                            *slot += signs[t] * coef * r.get(map(t));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_classifies_exact_bounds() {
        let p = partition_of(&[0.0, 0.5, 2.0, 1.9999], 2.0);
        assert_eq!(
            p,
            vec![VarBound::Lower, VarBound::Free, VarBound::Upper, VarBound::Free]
        );
    }

    #[test]
    fn full_set_never_reports_shrunk() {
        let mut a = ActiveSet::full(5);
        assert!(a.is_full());
        assert_eq!(a.indices(), &[0, 1, 2, 3, 4]);
        // tick fires once per interval (= n here)
        let fires: usize = (0..10).filter(|_| a.tick()).count();
        assert_eq!(fires, 2);
    }

    #[test]
    fn shrink_drops_only_nonviolating_bounded() {
        // signs all +1 (one-class-like): var 0 free, var 1 upper+violating
        // (in I_up? no: s>0 upper is I_low-only; violating as j when
        // gmax1 + g > 0), var 2 upper+non-violating, var 3 lower+non-viol.
        let signs = [1.0, 1.0, 1.0, 1.0];
        let alpha = [0.5, 1.0, 1.0, 0.0];
        // gmax1 = max I_up −g = max(−g0, −g3); gmax2 = max I_low g = g0..g2
        let g = [0.0, 1.0, -3.0, 2.0];
        // gmax1 = max(0, −2) = 0; gmax2 = max(0, 1, −3) = 1 → violation 1
        let mut a = ActiveSet::full(4);
        a.shrink(&signs, &alpha, &g, 1.0, 1e-3);
        // upper s>0 shrinks when −g > gmax1: var1 (−1 > 0? no) kept,
        // var2 (3 > 0 ✓) dropped; lower s>0 shrinks when g > gmax2:
        // var3 (2 > 1 ✓) dropped.
        assert_eq!(a.indices(), &[0, 1]);
        assert!(!a.is_full());
        assert_eq!(a.passes(), 1);
        a.unshrink();
        assert!(a.is_full());
    }

    #[test]
    fn seeded_rejects_free_and_violating_guesses() {
        let signs = [1.0, -1.0, 1.0, -1.0];
        // var 0 free → must be rejected even if proposed; var 1 upper
        // (s<0, in I_up) with strongly non-violating gradient → accepted;
        // var 2 proposed but violating → rejected.
        let alpha = [0.5, 2.0, 0.0, 0.3];
        let g = [0.0, 5.0, -4.0, 0.0];
        // I_up: 0 (s>0,a<C), 1 (s<0,a>0), 2 (s>0,a<C), 3 (s<0,a>0)
        // gmax1 = max(−g0, g1, −g2, g3) = max(0, 5, 4, 0) = 5
        // I_low: 0, 3 and (s<0,a<C): 3 → gmax2 = max(g0, −g3) = 0
        let set = ActiveSet::seeded(4, &signs, &alpha, &g, 2.0, 1e-3, &[0, 1, 2]);
        // var1: upper s<0 shrinks when −g > gmax2 → −5 > 0 false → kept!
        // (it is the maximal violator); nothing else shrinkable → full.
        assert!(set.is_full());

        // flip var1's gradient so it is strictly non-violating
        let g2 = [0.0, -5.0, -4.0, 0.0];
        // gmax1 = max(0, −5, 4, 0) = 4 (var2 violates), gmax2 = 0
        let set = ActiveSet::seeded(4, &signs, &alpha, &g2, 2.0, 1e-3, &[0, 1, 2]);
        assert_eq!(set.indices(), &[0, 2, 3]);
        assert!(!set.is_full());
    }

    #[test]
    fn seeded_near_optimum_ignores_guess() {
        let signs = [1.0, 1.0];
        let alpha = [1.0, 0.0];
        let g = [0.0, 0.0]; // violation 0 ≤ 10·eps
        let set = ActiveSet::seeded(2, &signs, &alpha, &g, 1.0, 1e-3, &[0, 1]);
        assert!(set.is_full());
    }

    #[test]
    fn reconstruct_touches_only_inactive() {
        // 3 variables, identity map, p = −1, signs = +1, row(j) = e_j·2
        let active = [0usize, 2];
        let mut g = [7.0, 99.0, 8.0];
        let alpha = [0.5, 0.0, 1.0];
        let rows: Vec<KernelRow> = (0..3)
            .map(|j| {
                let mut r = vec![0.0; 3];
                r[j] = 2.0;
                KernelRow::from_f64(r, crate::kernel::CacheDtype::F64)
            })
            .collect();
        reconstruct_inactive(
            &mut g,
            &active,
            |_| -1.0,
            &[1.0, 1.0, 1.0],
            &alpha,
            |t| t,
            |j| rows[j].clone(),
        );
        assert_eq!(g[0], 7.0);
        assert_eq!(g[2], 8.0);
        // g1 = −1 + Σ_j α_j·row_j[1] = −1 (no row has column 1 mass)
        assert_eq!(g[1], -1.0);
    }
}
