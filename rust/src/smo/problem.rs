//! The three LibSVM core formulations as [`QpProblem`] instances, plus
//! the ε-SVR pair-variable helpers.
//!
//! Each problem builds the [`QpSpec`] the [`GeneralSolver`] consumes:
//!
//! - [`SvcProblem`] — binary C-SVC (the paper's setting; the specialised
//!   [`Solver`](super::Solver) remains the production path for it, this
//!   instance exists to cross-check the general solver against it);
//! - [`SvrProblem`] — ε-SVR with the doubled α/α* variables and the
//!   p-vector pᵢ = ε ∓ zᵢ;
//! - [`OneClassProblem`] — Schölkopf one-class SVM with p = 0, unit box
//!   and the Σα = ν·n equality constraint fixed by its initial point.

use super::solver::{GeneralSolver, QpProblem, QpSpec, SmoResult};
use crate::data::Dataset;

/// Binary C-SVC as a [`QpProblem`]: signs = labels, p = −1, identity map.
#[derive(Debug, Clone, Copy)]
pub struct SvcProblem {
    /// Penalty C (box constraint upper bound).
    pub c: f64,
}

impl QpProblem for SvcProblem {
    fn name(&self) -> &'static str {
        "c_svc"
    }

    fn spec(&self, ds: &Dataset) -> QpSpec {
        let n = ds.len();
        QpSpec {
            signs: ds.y.clone(),
            p: vec![-1.0; n],
            c: self.c,
            map: (0..n).collect(),
        }
    }

    fn initial_alpha(&self, ds: &Dataset) -> Vec<f64> {
        vec![0.0; ds.len()]
    }
}

/// ε-SVR as a [`QpProblem`] over 2n variables β = (α, α*):
///
/// ```text
///   min  ½ βᵀQβ + pᵀβ,   Q_ij = s_i·s_j·K(i mod n, j mod n)
///   s    = (+1, …, +1, −1, …, −1)
///   p_i  = ε − z_i   (α side),    p_{n+i} = ε + z_i   (α* side)
///   0 ≤ β ≤ C,   Σα − Σα* = 0
/// ```
///
/// The regression function is f(x) = Σᵢ (αᵢ − α*ᵢ)·K(xᵢ, x) − ρ with ρ
/// from the solver's bias (LibSVM's sign convention).
#[derive(Debug, Clone, Copy)]
pub struct SvrProblem {
    /// Penalty C (box constraint upper bound).
    pub c: f64,
    /// Tube half-width ε: residuals within ±ε cost nothing.
    pub epsilon: f64,
}

impl QpProblem for SvrProblem {
    fn name(&self) -> &'static str {
        "epsilon_svr"
    }

    fn spec(&self, ds: &Dataset) -> QpSpec {
        assert!(
            ds.is_regression(),
            "epsilon-SVR needs a regression dataset (Dataset::regression)"
        );
        assert!(self.epsilon >= 0.0, "epsilon must be >= 0");
        let n = ds.len();
        let mut signs = vec![1.0; 2 * n];
        signs[n..].iter_mut().for_each(|s| *s = -1.0);
        let mut p = Vec::with_capacity(2 * n);
        for &z in &ds.targets {
            p.push(self.epsilon - z);
        }
        for &z in &ds.targets {
            p.push(self.epsilon + z);
        }
        let map: Vec<usize> = (0..n).chain(0..n).collect();
        QpSpec {
            signs,
            p,
            c: self.c,
            map,
        }
    }

    fn initial_alpha(&self, ds: &Dataset) -> Vec<f64> {
        vec![0.0; 2 * ds.len()]
    }
}

/// One-class SVM (Schölkopf et al.) as a [`QpProblem`]: p = 0, unit box,
/// all signs +1. The constraint Σα = ν·n is established by
/// [`QpProblem::initial_alpha`] (LibSVM's ⌊νn⌋-ones-plus-fraction point)
/// and preserved by every SMO update.
#[derive(Debug, Clone, Copy)]
pub struct OneClassProblem {
    /// ν ∈ (0, 1]: upper bound on the outlier fraction / lower bound on
    /// the support-vector fraction.
    pub nu: f64,
}

impl QpProblem for OneClassProblem {
    fn name(&self) -> &'static str {
        "one_class"
    }

    fn spec(&self, ds: &Dataset) -> QpSpec {
        assert!(
            self.nu > 0.0 && self.nu <= 1.0,
            "nu must be in (0, 1], got {}",
            self.nu
        );
        let n = ds.len();
        QpSpec {
            signs: vec![1.0; n],
            p: vec![0.0; n],
            c: 1.0,
            map: (0..n).collect(),
        }
    }

    fn initial_alpha(&self, ds: &Dataset) -> Vec<f64> {
        oneclass_initial_alpha(self.nu, ds.len())
    }
}

/// LibSVM's feasible one-class start: the first ⌊ν·n⌋ variables at the
/// unit bound, one fractional remainder, the rest zero — the unique point
/// of this shape with Σα = ν·n.
pub fn oneclass_initial_alpha(nu: f64, n: usize) -> Vec<f64> {
    let mut alpha = vec![0.0; n];
    let total = nu * n as f64;
    let full = (total.floor() as usize).min(n);
    alpha.iter_mut().take(full).for_each(|a| *a = 1.0);
    if full < n {
        alpha[full] = total - full as f64;
    }
    alpha
}

/// Expand ε-SVR pair differences δ = α − α* (each in \[−C, C\]) into the
/// doubled feasible β = (max(δ, 0), max(−δ, 0)) the solver consumes.
/// The expansion is complementary (αᵢ·α*ᵢ = 0) and preserves
/// Σsᵢβᵢ = Σδᵢ.
pub fn expand_svr_pairs(delta: &[f64]) -> Vec<f64> {
    let n = delta.len();
    let mut beta = vec![0.0; 2 * n];
    for (i, &d) in delta.iter().enumerate() {
        if d > 0.0 {
            beta[i] = d;
        } else if d < 0.0 {
            beta[n + i] = -d;
        }
    }
    beta
}

/// Collapse a solved doubled β back to the pair differences
/// δᵢ = βᵢ − β_{n+i} — the dual coefficients of the regression function.
pub fn collapse_svr_pairs(beta: &[f64]) -> Vec<f64> {
    let n = beta.len() / 2;
    assert_eq!(beta.len(), 2 * n, "doubled vector must have even length");
    (0..n).map(|i| beta[i] - beta[n + i]).collect()
}

/// Per-instance tube residuals eᵢ = f(xᵢ) − zᵢ of a solved ε-SVR, read
/// directly off the solver's α-side gradient: for the α variable i,
/// Gᵢ = (ε − zᵢ) + Σⱼ δⱼK(i,j), and f(xᵢ) = ΣⱼδⱼK(i,j) − ρ, hence
/// eᵢ = Gᵢ − ε − ρ. These residuals are the ε-SVR analogue of the
/// paper's optimality indicators fᵢ and feed the SVR seeders.
pub fn svr_errors(result: &SmoResult, epsilon: f64) -> Vec<f64> {
    let n = result.g.len() / 2;
    (0..n).map(|i| result.g[i] - epsilon - result.b).collect()
}

/// Convenience: build a [`GeneralSolver`] for `problem` over `ds`.
pub fn solver_for(
    problem: &dyn QpProblem,
    ds: &Dataset,
    kernel: crate::kernel::Kernel,
    params: super::SmoParams,
) -> GeneralSolver {
    let spec = problem.spec(ds);
    GeneralSolver::new(crate::kernel::KernelEval::new(ds.clone(), kernel), spec, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Kernel, KernelEval};
    use crate::smo::{SmoParams, Solver};

    #[test]
    fn general_solver_matches_binary_on_csvc() {
        let ds = crate::data::synth::generate("heart", Some(80), 3);
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(0.2));
        let mut bin = Solver::new(eval.clone(), SmoParams::with_c(2.0));
        let rb = bin.solve();
        assert!(rb.converged);

        let mut gen = solver_for(&SvcProblem { c: 2.0 }, &ds, Kernel::rbf(0.2), SmoParams::with_c(2.0));
        let rg = gen.solve();
        assert!(rg.converged);
        assert!(
            (rg.objective - rb.objective).abs() < 1e-3 * rb.objective.abs().max(1.0),
            "objective: general {} vs binary {}",
            rg.objective,
            rb.objective
        );
        assert!((rg.b - rb.b).abs() < 5e-3, "bias {} vs {}", rg.b, rb.b);
    }

    #[test]
    fn svr_fits_sinc_within_tube() {
        let ds = crate::data::synth::generate_regression("sinc", Some(120), 7);
        let problem = SvrProblem { c: 10.0, epsilon: 0.1 };
        let mut solver = solver_for(&problem, &ds, Kernel::rbf(0.5), SmoParams::default());
        let r = solver.solve();
        assert!(r.converged);
        // equality constraint Σα − Σα* = 0 preserved from the zero start
        let n = ds.len();
        let sum: f64 = (0..n).map(|i| r.alpha[i] - r.alpha[n + i]).sum();
        assert!(sum.abs() < 1e-6, "sum delta = {sum}");
        // complementarity holds at the optimum for ε > 0; at the solver's
        // finite tolerance only tiny simultaneous activations can remain
        for i in 0..n {
            let both_free = r.alpha[i] > 0.05 && r.alpha[n + i] > 0.05;
            assert!(!both_free, "pair {i} has both alpha and alpha* active");
        }
        // most training residuals fall inside (a slack above) the ε-tube
        let delta = collapse_svr_pairs(&r.alpha);
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(0.5));
        let mut inside = 0usize;
        for t in 0..n {
            let f: f64 = (0..n).map(|j| delta[j] * eval.eval(j, t)).sum::<f64>() - r.b;
            if (f - ds.targets[t]).abs() <= 0.1 + 0.1 {
                inside += 1;
            }
        }
        assert!(
            inside as f64 >= 0.85 * n as f64,
            "only {inside}/{n} residuals near the tube"
        );
    }

    #[test]
    fn svr_errors_match_direct_evaluation() {
        let ds = crate::data::synth::generate_regression("sinc", Some(80), 11);
        let epsilon = 0.1;
        let problem = SvrProblem { c: 5.0, epsilon };
        let mut solver = solver_for(&problem, &ds, Kernel::rbf(0.5), SmoParams::default());
        let r = solver.solve();
        assert!(r.converged);
        let delta = collapse_svr_pairs(&r.alpha);
        let errs = svr_errors(&r, epsilon);
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(0.5));
        for t in 0..ds.len() {
            let f: f64 = (0..ds.len())
                .map(|j| delta[j] * eval.eval(j, t))
                .sum::<f64>()
                - r.b;
            assert!(
                (errs[t] - (f - ds.targets[t])).abs() < 1e-6,
                "residual {t}: {} vs {}",
                errs[t],
                f - ds.targets[t]
            );
        }
    }

    #[test]
    fn oneclass_flags_far_outliers() {
        let ds = crate::data::synth::generate_outliers(Some(200), 0.1, 5);
        let nu = 0.15;
        let problem = OneClassProblem { nu };
        let mut solver = solver_for(&problem, &ds, Kernel::rbf(1.0), SmoParams::default());
        let beta0 = problem.initial_alpha(&ds);
        let r = solver.solve_from(beta0, None);
        assert!(r.converged);
        // Σα = ν·n preserved
        let sum: f64 = r.alpha.iter().sum();
        assert!(
            (sum - nu * ds.len() as f64).abs() < 1e-6,
            "sum alpha {sum} vs nu*n {}",
            nu * ds.len() as f64
        );
        // decision d(x) = Σ αᵢK(xᵢ,x) − ρ: ground-truth outliers score lower
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(1.0));
        let dec: Vec<f64> = (0..ds.len())
            .map(|t| {
                (0..ds.len())
                    .map(|j| r.alpha[j] * eval.eval(j, t))
                    .sum::<f64>()
                    - r.b
            })
            .collect();
        let mean_of = |label: f64| {
            let vals: Vec<f64> = dec
                .iter()
                .zip(&ds.y)
                .filter(|(_, &y)| y == label)
                .map(|(&d, _)| d)
                .collect();
            vals.iter().sum::<f64>() / vals.len() as f64
        };
        assert!(
            mean_of(1.0) > mean_of(-1.0),
            "inliers should score above outliers: {} vs {}",
            mean_of(1.0),
            mean_of(-1.0)
        );
    }

    #[test]
    fn oneclass_initial_point_sums_to_nu_n() {
        for (nu, n) in [(0.1, 50), (0.5, 7), (1.0, 4), (0.3, 1)] {
            let a = oneclass_initial_alpha(nu, n);
            assert_eq!(a.len(), n);
            let sum: f64 = a.iter().sum();
            assert!((sum - nu * n as f64).abs() < 1e-12, "nu={nu} n={n}");
            assert!(a.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn expand_collapse_roundtrip() {
        let delta = vec![0.5, -1.25, 0.0, 2.0];
        let beta = expand_svr_pairs(&delta);
        assert_eq!(beta, vec![0.5, 0.0, 0.0, 2.0, 0.0, 1.25, 0.0, 0.0]);
        assert_eq!(collapse_svr_pairs(&beta), delta);
    }
}
