//! Stub for [`XlaBackend`] compiled when the `xla` cargo feature is off.
//!
//! The real backend (see `xla_backend.rs`) executes AOT JAX/Pallas
//! artifacts through the PJRT C API and needs the `xla` bindings crate,
//! which the offline build image does not carry. This stub keeps the
//! public surface identical — `default_dir()`, `load()`, [`XlaStats`],
//! and the [`ComputeBackend`] impl — so callers compile unchanged;
//! `load()` simply reports that the backend is disabled, and every call
//! site already falls back to the native path on load failure.

use super::backend::ComputeBackend;
use crate::data::Dataset;
use anyhow::{bail, Result};
use std::path::Path;

/// Call accounting (exposed for the ablation bench).
#[derive(Debug, Default, Clone, Copy)]
pub struct XlaStats {
    pub artifact_calls: u64,
    pub native_fallbacks: u64,
    pub compiles: u64,
}

/// AOT-artifact backend (disabled build: construction always fails).
pub struct XlaBackend {
    /// Call accounting; always zero in the stub.
    pub stats: XlaStats,
}

impl XlaBackend {
    /// Always fails: this binary was built without the `xla` feature.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaBackend> {
        let _ = dir.as_ref();
        bail!(
            "the XLA/PJRT backend is disabled in this build; \
             rebuild with `--features xla` (requires the xla bindings crate)"
        )
    }

    /// The default artifacts directory: $ALPHASEED_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var_os("ALPHASEED_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|| "artifacts".into())
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla(disabled)"
    }

    fn kernel_rows(
        &mut self,
        _ds: &Dataset,
        _gamma: f64,
        _queries: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        bail!("XLA backend disabled (built without the `xla` feature)")
    }

    fn kernel_cross_rows(
        &mut self,
        _sv: &Dataset,
        _gamma: f64,
        _data: &Dataset,
        _queries: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        bail!("XLA backend disabled (built without the `xla` feature)")
    }

    fn kernel_matvec(
        &mut self,
        _x: &Dataset,
        _w: &Dataset,
        _coef: &[f64],
        _gamma: f64,
    ) -> Result<Vec<f64>> {
        bail!("XLA backend disabled (built without the `xla` feature)")
    }
}
