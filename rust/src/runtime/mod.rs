//! Execution backends for bulk kernel computations.
//!
//! The solver's per-iteration row fetches stay native (PJRT dispatch costs
//! O(10µs) per call — measured in `benches/micro_hotpath.rs` — while a hit
//! in the LRU is O(1)); the *bulk* operations route through
//! [`ComputeBackend`]:
//!
//! - warm-start gradient initialisation `K(X, SV)·coef`,
//! - the SIR similarity block and seeding-cache prefill `K(Q, X)`,
//! - test-fold decision values.
//!
//! [`NativeBackend`] computes them on the CPU in rust; [`XlaBackend`] loads
//! the AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`, built by
//! `make artifacts`) and executes them through the PJRT C API — python is
//! never on this path.

mod backend;
mod manifest;
#[cfg(feature = "xla")]
mod xla_backend;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
mod xla_backend;

pub use backend::{BackendChoice, ComputeBackend, NativeBackend};
pub use manifest::{ArtifactManifest, ArtifactOp};
pub use xla_backend::{XlaBackend, XlaStats};

use crate::data::Dataset;
use anyhow::Result;

/// Convenience: decision values of a model over a dataset through any
/// backend ( Σᵢ coefᵢ·K(svᵢ, xⱼ) − b ).
pub fn decision_values_via(
    backend: &mut dyn ComputeBackend,
    sv: &Dataset,
    coef: &[f64],
    b: f64,
    gamma: f64,
    data: &Dataset,
) -> Result<Vec<f64>> {
    let mut vals = backend.kernel_matvec(data, sv, coef, gamma)?;
    for v in vals.iter_mut() {
        *v -= b;
    }
    Ok(vals)
}
