//! The [`ComputeBackend`] trait and its native (pure-rust) implementation.

use crate::data::Dataset;
use crate::kernel::{Kernel, KernelEval};
use anyhow::Result;

/// Which backend to use for bulk kernel computations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// Pure rust (always available).
    #[default]
    Native,
    /// AOT JAX/Pallas artifacts via PJRT; falls back to native per-call for
    /// shapes without a compiled bucket.
    Xla,
}

impl std::str::FromStr for BackendChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(BackendChoice::Native),
            "xla" => Ok(BackendChoice::Xla),
            other => Err(format!("unknown backend '{other}' (native|xla)")),
        }
    }
}

/// Bulk kernel computations (RBF only — the paper's kernel; the native
/// solver paths support the other kernels).
///
/// Deliberately NOT `Send`: the PJRT client handle is single-threaded, so
/// the coordinator creates one backend per worker thread instead of
/// sharing one.
pub trait ComputeBackend {
    fn name(&self) -> &'static str;

    /// Rows K(x_q, ·) over the whole dataset for each global query index,
    /// K(q, j) = exp(−γ‖x_q − x_j‖²). Returns one `ds.len()` row per query.
    fn kernel_rows(&mut self, ds: &Dataset, gamma: f64, queries: &[usize]) -> Result<Vec<Vec<f64>>>;

    /// Cross rows K(svᵩ, ·) over `data` for each query index into `sv` —
    /// the serving tier's batched primitive (one row per support vector
    /// per request batch). Returns one `data.len()` row per query.
    fn kernel_cross_rows(
        &mut self,
        sv: &Dataset,
        gamma: f64,
        data: &Dataset,
        queries: &[usize],
    ) -> Result<Vec<Vec<f64>>>;

    /// fⱼ = Σᵢ coefᵢ·K(wᵢ, xⱼ) for all rows xⱼ of `x` — the decision /
    /// gradient-init bulk primitive.
    fn kernel_matvec(&mut self, x: &Dataset, w: &Dataset, coef: &[f64], gamma: f64)
        -> Result<Vec<f64>>;
}

/// Pure-rust backend: same math as the solver's kernel path.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn kernel_rows(&mut self, ds: &Dataset, gamma: f64, queries: &[usize]) -> Result<Vec<Vec<f64>>> {
        let eval = KernelEval::new(ds.clone(), Kernel::rbf(gamma));
        let mut out = Vec::with_capacity(queries.len());
        for &q in queries {
            let mut row = vec![0.0f64; ds.len()];
            eval.eval_row(q, &mut row);
            out.push(row);
        }
        Ok(out)
    }

    fn kernel_cross_rows(
        &mut self,
        sv: &Dataset,
        gamma: f64,
        data: &Dataset,
        queries: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(sv.dim() == data.dim(), "SV/data width mismatch");
        let eval = KernelEval::new(sv.clone(), Kernel::rbf(gamma));
        let mut out = Vec::with_capacity(queries.len());
        for &q in queries {
            let mut row = vec![0.0f64; data.len()];
            eval.eval_cross_row(q, data, &mut row);
            out.push(row);
        }
        Ok(out)
    }

    fn kernel_matvec(
        &mut self,
        x: &Dataset,
        w: &Dataset,
        coef: &[f64],
        gamma: f64,
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(w.len() == coef.len(), "coef/W length mismatch");
        // SV-outer accumulation over vectorized cross-row fills; for each
        // output j the terms land in ascending-i order, the same operation
        // sequence as the models' bulk path (`kernel_sums_minus_b`).
        let eval = KernelEval::new(w.clone(), Kernel::rbf(gamma));
        let mut acc = vec![0.0f64; x.len()];
        let mut krow = vec![0.0f64; x.len()];
        for (i, &c) in coef.iter().enumerate() {
            eval.eval_cross_row(i, x, &mut krow);
            for (a, &k) in acc.iter_mut().zip(&krow) {
                *a += c * k;
            }
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataMatrix;

    fn ds() -> Dataset {
        crate::data::synth::generate("heart", Some(30), 3)
    }

    #[test]
    fn rows_match_kernel_eval() {
        let d = ds();
        let mut b = NativeBackend;
        let rows = b.kernel_rows(&d, 0.2, &[0, 5, 29]).unwrap();
        let eval = KernelEval::new(d.clone(), Kernel::rbf(0.2));
        for (qi, &q) in [0usize, 5, 29].iter().enumerate() {
            for j in 0..d.len() {
                assert!((rows[qi][j] - eval.eval(q, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cross_rows_match_pointwise_eval() {
        let d = ds();
        let sv = d.select(&[2, 9, 17]);
        let mut b = NativeBackend;
        let rows = b.kernel_cross_rows(&sv, 0.2, &d, &[0, 2]).unwrap();
        let eval = KernelEval::new(sv.clone(), Kernel::rbf(0.2));
        for (qi, &q) in [0usize, 2].iter().enumerate() {
            assert_eq!(rows[qi].len(), d.len());
            for j in 0..d.len() {
                assert_eq!(
                    rows[qi][j].to_bits(),
                    eval.eval_cross(q, &d, j).to_bits(),
                    "query {q} col {j}"
                );
            }
        }
    }

    #[test]
    fn matvec_matches_manual() {
        let d = ds();
        let w = d.select(&[1, 3, 7]);
        let coef = [0.5, -1.0, 0.25];
        let mut b = NativeBackend;
        let out = b.kernel_matvec(&d, &w, &coef, 0.2).unwrap();
        let eval = KernelEval::new(w.clone(), Kernel::rbf(0.2));
        for j in 0..d.len() {
            let expect: f64 = (0..3).map(|i| coef[i] * eval.eval_cross(i, &d, j)).sum();
            assert!((out[j] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn decision_values_via_subtracts_bias() {
        let d = Dataset::new(
            "t",
            DataMatrix::dense(2, 1, vec![0.0, 1.0]),
            vec![1.0, -1.0],
        );
        let mut b = NativeBackend;
        let vals =
            super::super::decision_values_via(&mut b, &d, &[1.0, -1.0], 0.25, 1.0, &d).unwrap();
        // d(x0) = K(0,0) − K(1,0) − 0.25 = 1 − e^{−1} − 0.25
        let expect = 1.0 - (-1.0f64).exp() - 0.25;
        assert!((vals[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!("native".parse::<BackendChoice>().unwrap(), BackendChoice::Native);
        assert_eq!("xla".parse::<BackendChoice>().unwrap(), BackendChoice::Xla);
        assert!("gpu".parse::<BackendChoice>().is_err());
    }
}
