//! PJRT-backed implementation of [`ComputeBackend`].
//!
//! Loads HLO-text artifacts produced by `python/compile/aot.py` (JAX/Pallas
//! lowered once at build time), compiles them on the PJRT CPU client and
//! executes them for bulk kernel computations. HLO **text** is the
//! interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that the crate's xla_extension 0.5.1 rejects; the text parser reassigns
//! ids (see /opt/xla-example/README.md).
//!
//! Shape discipline: every artifact is compiled for a fixed (b, n, d)
//! bucket. Inputs are zero-padded up to the bucket — zero-padded *features*
//! leave RBF distances unchanged, zero-padded *coefficients* contribute
//! nothing to the matvec, and padded rows/queries are sliced off the
//! output. Shapes with no fitting bucket fall back to the native backend
//! (counted in [`XlaStats`]).

use super::backend::{ComputeBackend, NativeBackend};
use super::manifest::{ArtifactManifest, ArtifactOp};
use crate::data::Dataset;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Call accounting (exposed for the ablation bench).
#[derive(Debug, Default, Clone, Copy)]
pub struct XlaStats {
    pub artifact_calls: u64,
    pub native_fallbacks: u64,
    pub compiles: u64,
}

/// AOT-artifact backend.
pub struct XlaBackend {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    fallback: NativeBackend,
    /// Padded dense feature cache keyed by (name, rows, cols, content
    /// fingerprint, n_pad, d_pad). CV reuses the same full dataset for
    /// every seeding call, so this hits constantly; the fingerprint (sum
    /// of squared norms) keeps distinct `select()` subsets with colliding
    /// names/shapes apart.
    padded: HashMap<(String, usize, usize, u64, usize, usize), Vec<f32>>,
    pub stats: XlaStats,
}

impl XlaBackend {
    /// Load the manifest in `dir` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaBackend> {
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(XlaBackend {
            client,
            manifest,
            compiled: HashMap::new(),
            fallback: NativeBackend,
            padded: HashMap::new(),
            stats: XlaStats::default(),
        })
    }

    /// The default artifacts directory: $ALPHASEED_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var_os("ALPHASEED_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|| "artifacts".into())
    }

    fn executable(&mut self, op: &ArtifactOp) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(&op.file) {
            let path = self.manifest.path_of(op);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.stats.compiles += 1;
            self.compiled.insert(op.file.clone(), exe);
        }
        Ok(&self.compiled[&op.file])
    }

    /// Dense, zero-padded [n_pad × d_pad] copy of the dataset features.
    fn padded_features(&mut self, ds: &Dataset, n_pad: usize, d_pad: usize) -> Vec<f32> {
        let fingerprint = ds.sq_norms.iter().sum::<f64>().to_bits();
        let key = (ds.name.clone(), ds.len(), ds.dim(), fingerprint, n_pad, d_pad);
        if let Some(buf) = self.padded.get(&key) {
            return buf.clone();
        }
        let buf = pad_rows(&ds.x.to_dense_vec(), ds.len(), ds.dim(), n_pad, d_pad);
        self.padded.insert(key, buf.clone());
        buf
    }

    fn run(
        &mut self,
        op: &ArtifactOp,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(op)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .context("artifact execution")?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Zero-pad a row-major [rows × cols] buffer to [n_pad × d_pad].
fn pad_rows(src: &[f32], rows: usize, cols: usize, n_pad: usize, d_pad: usize) -> Vec<f32> {
    debug_assert!(n_pad >= rows && d_pad >= cols);
    let mut out = vec![0.0f32; n_pad * d_pad];
    for r in 0..rows {
        out[r * d_pad..r * d_pad + cols].copy_from_slice(&src[r * cols..(r + 1) * cols]);
    }
    out
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn kernel_rows(&mut self, ds: &Dataset, gamma: f64, queries: &[usize]) -> Result<Vec<Vec<f64>>> {
        let (n, d) = (ds.len(), ds.dim());
        let Some(op) = self.manifest.find_bucket("rbf_rows", 1, n, d).cloned() else {
            self.stats.native_fallbacks += 1;
            return self.fallback.kernel_rows(ds, gamma, queries);
        };
        let x_pad = self.padded_features(ds, op.n, op.d);
        let x_lit = xla::Literal::vec1(&x_pad).reshape(&[op.n as i64, op.d as i64])?;
        let gamma_lit = xla::Literal::vec1(&[gamma as f32]);

        let dense = ds.x.to_dense_vec();
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(op.b) {
            // Pack the chunk's rows into the padded query block.
            let mut q_pad = vec![0.0f32; op.b * op.d];
            for (qi, &gq) in chunk.iter().enumerate() {
                q_pad[qi * op.d..qi * op.d + d].copy_from_slice(&dense[gq * d..(gq + 1) * d]);
            }
            let q_lit = xla::Literal::vec1(&q_pad).reshape(&[op.b as i64, op.d as i64])?;
            let flat = self.run(&op, &[x_lit.clone(), q_lit, gamma_lit.clone()])?;
            anyhow::ensure!(flat.len() == op.b * op.n, "artifact output shape mismatch");
            self.stats.artifact_calls += 1;
            for qi in 0..chunk.len() {
                out.push(
                    flat[qi * op.n..qi * op.n + n]
                        .iter()
                        .map(|&v| v as f64)
                        .collect(),
                );
            }
        }
        Ok(out)
    }

    fn kernel_cross_rows(
        &mut self,
        sv: &Dataset,
        gamma: f64,
        data: &Dataset,
        queries: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(sv.dim() == data.dim(), "SV/data width mismatch");
        let (n, d) = (data.len(), data.dim());
        // Same artifact as kernel_rows — rbf_rows computes K(Q, X) for an
        // arbitrary padded query block, so cross rows just pass `data` as X
        // and the support vectors as queries.
        let Some(op) = self.manifest.find_bucket("rbf_rows", 1, n, d).cloned() else {
            self.stats.native_fallbacks += 1;
            return self.fallback.kernel_cross_rows(sv, gamma, data, queries);
        };
        let x_pad = self.padded_features(data, op.n, op.d);
        let x_lit = xla::Literal::vec1(&x_pad).reshape(&[op.n as i64, op.d as i64])?;
        let gamma_lit = xla::Literal::vec1(&[gamma as f32]);

        let sv_dense = sv.x.to_dense_vec();
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(op.b) {
            let mut q_pad = vec![0.0f32; op.b * op.d];
            for (qi, &gq) in chunk.iter().enumerate() {
                q_pad[qi * op.d..qi * op.d + d].copy_from_slice(&sv_dense[gq * d..(gq + 1) * d]);
            }
            let q_lit = xla::Literal::vec1(&q_pad).reshape(&[op.b as i64, op.d as i64])?;
            let flat = self.run(&op, &[x_lit.clone(), q_lit, gamma_lit.clone()])?;
            anyhow::ensure!(flat.len() == op.b * op.n, "artifact output shape mismatch");
            self.stats.artifact_calls += 1;
            for qi in 0..chunk.len() {
                out.push(
                    flat[qi * op.n..qi * op.n + n]
                        .iter()
                        .map(|&v| v as f64)
                        .collect(),
                );
            }
        }
        Ok(out)
    }

    fn kernel_matvec(
        &mut self,
        x: &Dataset,
        w: &Dataset,
        coef: &[f64],
        gamma: f64,
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(w.len() == coef.len(), "coef/W length mismatch");
        anyhow::ensure!(x.dim() == w.dim(), "X/W width mismatch");
        let (n, d, m) = (x.len(), x.dim(), w.len());
        let Some(op) = self.manifest.find_bucket("rbf_matvec", m, n, d).cloned() else {
            self.stats.native_fallbacks += 1;
            return self.fallback.kernel_matvec(x, w, coef, gamma);
        };
        let x_pad = self.padded_features(x, op.n, op.d);
        let w_pad = pad_rows(&w.x.to_dense_vec(), m, d, op.b, op.d);
        let mut coef_pad = vec![0.0f32; op.b];
        for (i, &c) in coef.iter().enumerate() {
            coef_pad[i] = c as f32;
        }
        let x_lit = xla::Literal::vec1(&x_pad).reshape(&[op.n as i64, op.d as i64])?;
        let w_lit = xla::Literal::vec1(&w_pad).reshape(&[op.b as i64, op.d as i64])?;
        let c_lit = xla::Literal::vec1(&coef_pad);
        let g_lit = xla::Literal::vec1(&[gamma as f32]);
        let flat = self.run(&op, &[x_lit, w_lit, c_lit, g_lit])?;
        anyhow::ensure!(flat.len() == op.n, "artifact output shape mismatch");
        self.stats.artifact_calls += 1;
        Ok(flat[..n].iter().map(|&v| v as f64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rows_layout() {
        // 2x2 → 3x4
        let out = pad_rows(&[1., 2., 3., 4.], 2, 2, 3, 4);
        assert_eq!(
            out,
            vec![1., 2., 0., 0., 3., 4., 0., 0., 0., 0., 0., 0.]
        );
    }

    // End-to-end artifact execution is covered by rust/tests/xla_runtime.rs
    // (requires `make artifacts` to have run).
}
