//! The AOT artifact manifest: which HLO files exist, for which op and
//! shape bucket. Written by `python/compile/aot.py`, read here.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactOp {
    /// "rbf_rows" (K(Q,X) block) or "rbf_matvec" (K(X,W)·coef).
    pub op: String,
    /// Max query batch (rows) / max W rows (matvec).
    pub b: usize,
    /// Padded dataset rows.
    pub n: usize,
    /// Padded feature dimension.
    pub d: usize,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub ops: Vec<ArtifactOp>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<ArtifactManifest> {
        let root = Json::parse(text).context("manifest.json is not valid JSON")?;
        let ops_json = root
            .get("ops")
            .and_then(|o| o.as_arr())
            .context("manifest missing 'ops' array")?;
        let mut ops = Vec::with_capacity(ops_json.len());
        for (i, entry) in ops_json.iter().enumerate() {
            let field = |k: &str| -> Result<&Json> {
                entry.get(k).with_context(|| format!("ops[{i}] missing '{k}'"))
            };
            ops.push(ArtifactOp {
                op: field("op")?.as_str().context("op not a string")?.to_string(),
                b: field("b")?.as_usize().context("b not an int")?,
                n: field("n")?.as_usize().context("n not an int")?,
                d: field("d")?.as_usize().context("d not an int")?,
                file: field("file")?
                    .as_str()
                    .context("file not a string")?
                    .to_string(),
            });
        }
        Ok(ArtifactManifest { dir, ops })
    }

    /// Smallest bucket of `op` that fits (b, n, d); None when nothing fits.
    pub fn find_bucket(&self, op: &str, b: usize, n: usize, d: usize) -> Option<&ArtifactOp> {
        self.ops
            .iter()
            .filter(|o| o.op == op && o.b >= b && o.n >= n && o.d >= d)
            .min_by_key(|o| (o.n, o.d, o.b))
    }

    /// Absolute path of an op's HLO file.
    pub fn path_of(&self, op: &ArtifactOp) -> PathBuf {
        self.dir.join(&op.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "ops": [
        {"op": "rbf_rows",   "b": 128, "n": 512,  "d": 16,  "file": "rbf_rows_b128_n512_d16.hlo.txt"},
        {"op": "rbf_rows",   "b": 128, "n": 2048, "d": 128, "file": "rbf_rows_b128_n2048_d128.hlo.txt"},
        {"op": "rbf_matvec", "b": 512, "n": 512,  "d": 16,  "file": "rbf_matvec_b512_n512_d16.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.ops.len(), 3);
        assert_eq!(m.ops[0].op, "rbf_rows");
        assert_eq!(m.ops[1].n, 2048);
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        // fits the small bucket
        let b = m.find_bucket("rbf_rows", 10, 300, 13).unwrap();
        assert_eq!(b.n, 512);
        // needs the big one
        let b = m.find_bucket("rbf_rows", 10, 600, 100).unwrap();
        assert_eq!(b.n, 2048);
        // nothing fits
        assert!(m.find_bucket("rbf_rows", 10, 5000, 13).is_none());
        assert!(m.find_bucket("rbf_rows", 200, 300, 13).is_none());
        assert!(m.find_bucket("nope", 1, 1, 1).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse("{}", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse(r#"{"ops":[{"op":"x"}]}"#, PathBuf::new()).is_err());
    }

    #[test]
    fn path_joins_dir() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/art")).unwrap();
        assert_eq!(
            m.path_of(&m.ops[0]),
            PathBuf::from("/art/rbf_rows_b128_n512_d16.hlo.txt")
        );
    }
}
