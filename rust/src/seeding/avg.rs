//! AVG (DeCoste & Wagstaff 2000) — leave-one-out baseline.
//!
//! Train one SVM on the whole dataset; to seed the round that leaves out
//! x_t, distribute its weight y_t·α_t *uniformly* across the free support
//! vectors (0 < α < C), clamping at the box and re-spreading the overflow
//! among the instances that can still move (paper supplementary §AVG).
//!
//! Used in the Figure 2 leave-one-out comparison: the CV driver constructs
//! a SeedContext whose `prev_train` is the full index set, `removed` the
//! single left-out instance, and `added` empty.

use super::{pos_of, SeedContext, SeedResult, Seeder};
use crate::kernel::KernelCache;

/// Uniform redistribution over free support vectors.
#[derive(Debug, Default, Clone, Copy)]
pub struct Avg;

impl Seeder for Avg {
    fn name(&self) -> &'static str {
        "avg"
    }

    fn seed(&self, ctx: &SeedContext, _cache: &mut KernelCache) -> SeedResult {
        assert!(
            ctx.added.is_empty(),
            "AVG is a leave-one-out seeder: 𝒯 must be empty"
        );
        let c = ctx.c;
        let y = &ctx.full.y;
        let next = ctx.next_train;

        // Copy all surviving α.
        let mut alpha = vec![0.0f64; next.len()];
        for (p, &gi) in ctx.prev_train.iter().enumerate() {
            if let Some(np) = pos_of(next, gi) {
                alpha[np] = ctx.prev_alpha[p];
            }
        }

        // Mass to redistribute: Σ over removed of y_t·α_t (normally one
        // instance in LOO, but the code handles a set).
        let mut residual: f64 = ctx
            .removed
            .iter()
            .map(|&gr| {
                let p = pos_of(ctx.prev_train, gr).expect("R ⊄ prev_train");
                y[gr] * ctx.prev_alpha[p]
            })
            .sum();

        if residual != 0.0 {
            // Iteratively spread over currently-free instances. In s-space
            // (s = y·α) we must *add* `residual` in total.
            for _pass in 0..64 {
                if residual.abs() < 1e-12 {
                    break;
                }
                let free: Vec<usize> = (0..alpha.len())
                    .filter(|&i| alpha[i] > 0.0 && alpha[i] < c)
                    .collect();
                if free.is_empty() {
                    break;
                }
                let share = residual / free.len() as f64;
                for &i in &free {
                    let yy = y[next[i]];
                    // s_i += share  →  α_i += y_i·share, clamped to the box
                    let desired = alpha[i] + yy * share;
                    let clamped = desired.clamp(0.0, c);
                    let moved = (clamped - alpha[i]) * yy; // in s-space
                    alpha[i] = clamped;
                    residual -= moved;
                }
            }
        }

        if residual.abs() > 1e-9 {
            // Free set saturated: spread the leftover over *all* instances.
            let ny: Vec<f64> = next.iter().map(|&gi| y[gi]).collect();
            let total: f64 = alpha.iter().zip(&ny).map(|(a, yy)| a * yy).sum();
            if !super::balance_to_target(&mut alpha, &ny, c, total + residual) {
                return SeedResult {
                    alpha: vec![0.0; next.len()],
                    fell_back: true,
                };
            }
        }

        SeedResult {
            alpha,
            fell_back: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::FoldPlan;
    use crate::kernel::{Kernel, KernelEval};
    use crate::seeding::check_feasible;
    use crate::smo::{SmoParams, Solver};

    /// Build a LOO-style context: prev = full solve, removed = {t}.
    fn loo_ctx(
        n: usize,
        t: usize,
    ) -> (
        crate::data::Dataset,
        Vec<usize>,
        Vec<f64>,
        Vec<f64>,
        f64,
        Vec<usize>,
        Vec<usize>,
    ) {
        let full = crate::data::synth::generate("heart", Some(n), 33);
        let kernel = Kernel::rbf(0.2);
        let mut solver = Solver::new(KernelEval::new(full.clone(), kernel), SmoParams::with_c(2.0));
        let r = solver.solve();
        assert!(r.converged);
        let f = r.f_indicators(&full.y);
        let prev_train: Vec<usize> = (0..n).collect();
        let plan = FoldPlan::leave_one_out(n);
        let next_train = plan.train_indices(t);
        (full, prev_train, r.alpha, f, r.b, vec![t], next_train)
    }

    #[test]
    fn loo_seed_feasible_and_close() {
        let (full, prev_train, prev_alpha, prev_f, prev_b, removed, next_train) = loo_ctx(80, 3);
        let ctx = SeedContext {
            full: &full,
            kernel: Kernel::rbf(0.2),
            c: 2.0,
            prev_train: &prev_train,
            prev_alpha: &prev_alpha,
            prev_f: &prev_f,
            prev_b,
            removed: &removed,
            added: &[],
            next_train: &next_train,
            rng_seed: 1,
        };
        let mut cache = KernelCache::with_byte_budget(
            KernelEval::new(full.clone(), Kernel::rbf(0.2)),
            16 << 20,
        );
        let r = Avg.seed(&ctx, &mut cache);
        let y: Vec<f64> = next_train.iter().map(|&i| full.y[i]).collect();
        check_feasible(&r.alpha, &y, 2.0).unwrap();

        // Seeding from the full model should converge in far fewer
        // iterations than cold start.
        let train = full.select(&next_train);
        let mut s_warm = Solver::new(
            KernelEval::new(train.clone(), Kernel::rbf(0.2)),
            SmoParams::with_c(2.0),
        );
        let rw = s_warm.solve_from(r.alpha, None);
        let mut s_cold = Solver::new(KernelEval::new(train, Kernel::rbf(0.2)), SmoParams::with_c(2.0));
        let rc = s_cold.solve();
        assert!(rw.converged && rc.converged);
        assert!(
            rw.iterations < rc.iterations,
            "AVG warm {} vs cold {}",
            rw.iterations,
            rc.iterations
        );
    }

    #[test]
    #[should_panic(expected = "leave-one-out")]
    fn rejects_kfold_context() {
        let full = crate::data::synth::generate("heart", Some(30), 1);
        let prev: Vec<usize> = (0..30).collect();
        let alpha = vec![0.0; 30];
        let f = vec![0.0; 30];
        let ctx = SeedContext {
            full: &full,
            kernel: Kernel::rbf(0.2),
            c: 1.0,
            prev_train: &prev,
            prev_alpha: &alpha,
            prev_f: &f,
            prev_b: 0.0,
            removed: &[0],
            added: &[1], // non-empty 𝒯 → panic
            next_train: &prev,
            rng_seed: 0,
        };
        let mut cache = KernelCache::with_byte_budget(
            KernelEval::new(full.clone(), Kernel::rbf(0.2)),
            1 << 20,
        );
        let _ = Avg.seed(&ctx, &mut cache);
    }
}
