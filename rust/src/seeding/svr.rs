//! SVR-aware alpha seeding — the paper's three rules transferred to the
//! ε-SVR pair variables (docs/SEEDING.md §"Transfer to ε-SVR" derives the
//! mapping).
//!
//! The doubled ε-SVR dual has the *same constraint structure* as the
//! binary C-SVC dual once expressed in the pair differences
//! δᵢ = αᵢ − α*ᵢ: a box (δᵢ ∈ \[−C, C\]) and one linear equality
//! (Σᵢ δᵢ = 0, which is exactly Σᵢ signsᵢ·βᵢ = 0 of the doubled QP).
//! Every seeder here therefore estimates a feasible δ for round h+1 from
//! round h's solved SVR; the CV driver expands δ into the doubled
//! β = (max(δ,0), max(−δ,0)) — complementary and box-feasible by
//! construction — and hands it to the
//! [`GeneralSolver`](crate::smo::GeneralSolver).
//!
//! | Seeder | C-SVC original | δ-space transfer |
//! |--------|----------------|------------------|
//! | [`SvrCold`] | α = 0 | δ = 0 |
//! | [`SvrAto`] | §3.1 ramp with margin-set compensation | drain δ_𝓡 onto the most-similar shared instances with box headroom |
//! | [`SvrMir`] | §3.2 Eq. 18 least squares | K(X,𝒯)·δ_𝒯 ≈ Δf + K(X,𝓡)·δ_𝓡 with tube-edge Δf, plus the Σδ row |
//! | [`SvrSir`] | §3.3 similarity transplant | transplant each δ_p onto the most similar unused 𝒯 instance |

use super::{balance_to_target, pos_of};
use crate::data::Dataset;
use crate::kernel::{Kernel, KernelCache};
use crate::linalg::{lstsq, Mat};

/// Everything an SVR seeder may use from round h to initialise round h+1.
/// All index slices hold **global** indices into `full`, sorted ascending
/// except `removed`/`added` (fold order) — the same layout as the
/// classification [`SeedContext`](super::SeedContext).
pub struct SvrSeedContext<'a> {
    /// The complete regression dataset (all k folds).
    pub full: &'a Dataset,
    /// The kernel both rounds train with.
    pub kernel: Kernel,
    /// The box constraint C both rounds train with (δ ∈ \[−C, C\]).
    pub c: f64,
    /// The tube half-width ε both rounds train with.
    pub epsilon: f64,
    /// Round h's training instances.
    pub prev_train: &'a [usize],
    /// Round h's optimal pair differences δ = α − α*, aligned with
    /// `prev_train`.
    pub prev_delta: &'a [f64],
    /// Round h's tube residuals eᵢ = f(xᵢ) − zᵢ, aligned with
    /// `prev_train` (the ε-SVR optimality indicator; see
    /// [`svr_errors`](crate::smo::problem::svr_errors)).
    pub prev_err: &'a [f64],
    /// Round h's bias ρ.
    pub prev_b: f64,
    /// 𝓡: leaving the training set (fold h+1).
    pub removed: &'a [usize],
    /// 𝒯: entering the training set (fold h, round h's test set).
    pub added: &'a [usize],
    /// Round h+1's training instances (= prev_train ∖ 𝓡 ∪ 𝒯, sorted).
    pub next_train: &'a [usize],
    /// Deterministic seed for any stochastic tie-breaking (none of the
    /// current rules need it; kept for parity with the C-SVC contract).
    pub rng_seed: u64,
}

/// Outcome of an SVR seeding step.
#[derive(Debug, Clone)]
pub struct SvrSeedResult {
    /// Pair differences δ aligned with `ctx.next_train`, feasible:
    /// δᵢ ∈ \[−C, C\] and Σᵢ δᵢ = 0.
    pub delta: Vec<f64>,
    /// True if the algorithm fell back to the cold start (δ = 0).
    pub fell_back: bool,
}

/// An ε-SVR alpha-seeding strategy over pair differences. The contract
/// mirrors [`Seeder`](super::Seeder): **feasibility** (box + Σδ = 0,
/// checked by [`check_feasible_delta`]), **determinism**, and **no effect
/// on the solution** — the solver's fixed point is independent of its
/// start, so seeded CV reports the same fold MSE as cold-started CV (up
/// to the solver's convergence tolerance).
pub trait SvrSeeder: Send + Sync {
    /// Short name for tables ("sir", "mir", ...).
    fn name(&self) -> &'static str;

    /// Produce a feasible δ for round h+1. `cache` is an LRU of kernel
    /// rows over the **full** dataset (global indices), shared across the
    /// whole cross-validation run.
    fn seed(&self, ctx: &SvrSeedContext, cache: &mut KernelCache) -> SvrSeedResult;

    /// Optional cross-fold active-set carry-over for the **doubled**
    /// (α, α*) variables: map round h's terminal bound partition
    /// (`prev_partition`, length `2·|prev_train|`, see
    /// [`SmoResult::partition`](crate::smo::SmoResult)) onto round h+1's
    /// doubled layout and return the β positions to propose as initially
    /// shrunk. Default `None` (full active set); the seeded rules
    /// override it with the δ-pair-aware [`carry_bounded_pairs`]. As in
    /// the classification chain the solver validates every proposed
    /// position against the fresh gradient, so the guess can only cost
    /// time, never correctness.
    fn seed_active_set(
        &self,
        ctx: &SvrSeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        let _ = (ctx, prev_partition);
        None
    }
}

/// The δ-pair-aware carry-over transfer: a shared instance is proposed as
/// initially shrunk **only when both of its doubled components were
/// bounded** in round h — |δ| = C (α side at C, α* side at 0, or the
/// mirror) or δ = 0 off the tube (both sides at 0). A free δ leaves one
/// component inside the box, and LibSVM's ε-SVR solver keeps such pairs
/// active as a unit; proposing half a pair would let the shrink criterion
/// split it. Returned positions are ascending in the doubled layout
/// (α side `np`, α* side `n_next + np`).
pub fn carry_bounded_pairs(
    prev_train: &[usize],
    prev_partition: &[crate::smo::VarBound],
    next_train: &[usize],
) -> Vec<usize> {
    use crate::smo::VarBound::Free;
    let n_prev = prev_train.len();
    debug_assert_eq!(prev_partition.len(), 2 * n_prev);
    let n_next = next_train.len();
    let mut shared_np = Vec::new();
    for (p, &gi) in prev_train.iter().enumerate() {
        if prev_partition[p] != Free && prev_partition[n_prev + p] != Free {
            if let Some(np) = pos_of(next_train, gi) {
                shared_np.push(np);
            }
        }
    }
    let mut out = Vec::with_capacity(2 * shared_np.len());
    out.extend(shared_np.iter().copied());
    out.extend(shared_np.iter().map(|&np| n_next + np));
    out
}

/// Cold start: δ = 0 (LibSVM semantics for ε-SVR).
#[derive(Debug, Default, Clone, Copy)]
pub struct SvrCold;

impl SvrSeeder for SvrCold {
    fn name(&self) -> &'static str {
        "cold"
    }

    fn seed(&self, ctx: &SvrSeedContext, _cache: &mut KernelCache) -> SvrSeedResult {
        SvrSeedResult {
            delta: vec![0.0; ctx.next_train.len()],
            fell_back: false,
        }
    }
}

/// Single Instance Replacement in δ-space: copy δ_𝓢 unchanged, then
/// transplant each removed δ_p (largest |δ| first) onto the most similar
/// unused 𝒯 instance — maximal K(x_p, x_t), served by one cached kernel
/// row per removed support vector. Transplanting the signed value keeps
/// Σδ exactly; any residual (|𝒯| smaller than 𝓡's support) is repaired
/// by the δ-space *AdjustAlpha* ([`balance_delta`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct SvrSir;

impl SvrSeeder for SvrSir {
    fn name(&self) -> &'static str {
        "sir"
    }

    fn seed(&self, ctx: &SvrSeedContext, cache: &mut KernelCache) -> SvrSeedResult {
        let mut delta = copy_shared(ctx);
        let r_delta = removed_deltas(ctx);
        // donors that outnumber 𝒯 are skipped; the balance below absorbs
        // the resulting residual
        super::transplant_by_similarity(
            ctx.removed,
            &r_delta,
            ctx.added,
            ctx.next_train,
            cache,
            |np, w| delta[np] = w,
        );
        finish_with_added_balance(ctx, delta)
    }

    fn seed_active_set(
        &self,
        ctx: &SvrSeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        Some(carry_bounded_pairs(
            ctx.prev_train,
            prev_partition,
            ctx.next_train,
        ))
    }
}

/// Multiple Instance Replacement in δ-space (the Eq. 18 analogue): keep
/// δ_𝓢 unchanged and solve one least-squares system for δ_𝒯,
///
/// ```text
///   [ K(X,T) ]            [ Δf + K(X,R)·δ_R ]
///   [  1ᵀ    ] · δ'_T  ≈  [     Σ_r δ_r     ]
/// ```
///
/// where Δfᵢ pushes each *bounded* residual to its KKT tube edge
/// (δᵢ = +C ⇒ eᵢ → −ε, δᵢ = −C ⇒ eᵢ → +ε) and leaves free/inactive
/// instances in place — exactly the paper's Δf = b − f rule translated
/// through the SVR optimality conditions. The solution is clipped to the
/// box and rebalanced so Σ_t δ'_t = Σ_r δ_r (the Eq. 16 analogue).
#[derive(Debug, Default, Clone, Copy)]
pub struct SvrMir;

impl SvrSeeder for SvrMir {
    fn name(&self) -> &'static str {
        "mir"
    }

    fn seed(&self, ctx: &SvrSeedContext, cache: &mut KernelCache) -> SvrSeedResult {
        let n = ctx.prev_train.len();
        let nt = ctx.added.len();
        let next = ctx.next_train;
        let c = ctx.c;
        let mut delta = copy_shared(ctx);

        let r_delta = removed_deltas(ctx);
        let target: f64 = r_delta.iter().sum();

        if nt == 0 {
            // degenerate (LOO-style) transition: rebalance the copy
            let mut d = delta.clone();
            let fell_back = !balance_delta(&mut d, c, 0.0);
            return SvrSeedResult {
                delta: if fell_back { vec![0.0; next.len()] } else { d },
                fell_back,
            };
        }

        // rhs_i = Δfᵢ + Σ_r δ_r·K(i, r);  rhs_n = Σ_r δ_r
        let mut rhs = vec![0.0f64; n + 1];
        for (i, _gi) in ctx.prev_train.iter().enumerate() {
            let d = ctx.prev_delta[i];
            let e = ctx.prev_err[i];
            rhs[i] = if d >= c {
                -ctx.epsilon - e
            } else if d <= -c {
                ctx.epsilon - e
            } else {
                0.0
            };
        }
        for (ri, &gr) in ctx.removed.iter().enumerate() {
            let dr = r_delta[ri];
            if dr == 0.0 {
                continue;
            }
            let row = cache.row(gr);
            for (i, &gi) in ctx.prev_train.iter().enumerate() {
                rhs[i] += dr * row.get(gi);
            }
        }
        rhs[n] = target;

        // A = [K(X,T); 1ᵀ], column t = K(X, x_t).
        let mut a_mat = Mat::zeros(n + 1, nt);
        for (t, &gt) in ctx.added.iter().enumerate() {
            let row = cache.row(gt);
            for (i, &gi) in ctx.prev_train.iter().enumerate() {
                a_mat[(i, t)] = row.get(gi);
            }
            a_mat[(n, t)] = 1.0;
        }

        let mut dt = match lstsq(&a_mat, &rhs) {
            Ok(x) => x,
            Err(_) => {
                let ata = a_mat.t().matmul(&a_mat);
                let atb = a_mat.t_matvec(&rhs);
                ata.pinv().matvec(&atb)
            }
        };

        // AdjustAlpha in δ-space: clip to [−C, C] + rebalance to Eq. 16.
        if !balance_delta(&mut dt, c, target) {
            return SvrSeedResult {
                delta: vec![0.0; next.len()],
                fell_back: true,
            };
        }
        for (t, &gt) in ctx.added.iter().enumerate() {
            let np = pos_of(next, gt).expect("T ⊄ next_train");
            delta[np] = dt[t];
        }
        SvrSeedResult {
            delta,
            fell_back: false,
        }
    }

    fn seed_active_set(
        &self,
        ctx: &SvrSeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        Some(carry_bounded_pairs(
            ctx.prev_train,
            prev_partition,
            ctx.next_train,
        ))
    }
}

/// Adjusting Alpha Towards Optimum in δ-space: drain each removed δ_r to
/// zero and deposit the drained (signed) mass onto the shared instances
/// most similar to x_r that still have box headroom in that direction —
/// the first-order counterpart of Algorithm 1's ramp, where the margin
/// set absorbs the change (fresh 𝒯 instances stay at δ = 0: unlike the
/// C-SVC case their optimal sign is unknown before solving). Saturating
/// every candidate leaves a residual that the δ-space *AdjustAlpha*
/// repairs.
#[derive(Debug, Clone, Copy)]
pub struct SvrAto {
    /// Numerical floor below which a δ is treated as drained to 0.
    pub drain_tol: f64,
}

impl Default for SvrAto {
    fn default() -> Self {
        SvrAto { drain_tol: 1e-10 }
    }
}

impl SvrSeeder for SvrAto {
    fn name(&self) -> &'static str {
        "ato"
    }

    fn seed(&self, ctx: &SvrSeedContext, cache: &mut KernelCache) -> SvrSeedResult {
        let next = ctx.next_train;
        let c = ctx.c;
        let mut delta = copy_shared(ctx);

        // Shared positions in next (candidates for compensation).
        let shared_pos: Vec<usize> = ctx
            .prev_train
            .iter()
            .filter(|&&gi| !ctx.removed.contains(&gi))
            .filter_map(|&gi| pos_of(next, gi))
            .collect();

        let r_delta = removed_deltas(ctx);
        let mut order: Vec<usize> = (0..ctx.removed.len()).collect();
        order.sort_by(|&a, &b| r_delta[b].abs().partial_cmp(&r_delta[a].abs()).unwrap());

        for &ri in &order {
            let dp = r_delta[ri];
            if dp.abs() <= self.drain_tol {
                continue;
            }
            let gp = ctx.removed[ri];
            let row_p = cache.row(gp);
            // candidates with headroom toward sign(dp), most similar first
            let mut cands: Vec<(usize, f64)> = shared_pos
                .iter()
                .filter_map(|&np| {
                    let head = if dp > 0.0 { c - delta[np] } else { delta[np] + c };
                    (head > self.drain_tol).then(|| (np, row_p.get(next[np])))
                })
                .collect();
            cands.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut remaining = dp;
            for (np, _) in cands {
                if remaining.abs() <= self.drain_tol {
                    break;
                }
                let head = if remaining > 0.0 {
                    c - delta[np]
                } else {
                    delta[np] + c
                };
                let take = remaining.abs().min(head) * remaining.signum();
                delta[np] += take;
                remaining -= take;
            }
            // any residual stays unplaced; the balance below repairs it
        }

        finish_with_whole_balance(ctx, delta)
    }

    fn seed_active_set(
        &self,
        ctx: &SvrSeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        // The drain may have moved shared δ; over-proposing is harmless —
        // the solver only shrinks positions bounded at the seeded β.
        Some(carry_bounded_pairs(
            ctx.prev_train,
            prev_partition,
            ctx.next_train,
        ))
    }
}

/// Look up an SVR seeder by canonical name (same names as the C-SVC
/// registry: "cold", "ato", "mir", "sir").
pub fn svr_seeder_by_name(name: &str) -> Option<Box<dyn SvrSeeder>> {
    match name {
        "cold" | "libsvm" => Some(Box::new(SvrCold)),
        "ato" => Some(Box::new(SvrAto::default())),
        "mir" => Some(Box::new(SvrMir)),
        "sir" => Some(Box::new(SvrSir)),
        _ => None,
    }
}

/// Names of the ε-SVR k-fold seeders, baseline first.
pub const ALL_SVR_SEEDERS: &[&str] = &["cold", "ato", "mir", "sir"];

/// Validate a δ vector against the ε-SVR feasibility contract:
/// δᵢ ∈ \[−C, C\] and Σᵢ δᵢ = 0.
pub fn check_feasible_delta(delta: &[f64], c: f64) -> Result<(), String> {
    for (i, &d) in delta.iter().enumerate() {
        if !(-c - 1e-9..=c + 1e-9).contains(&d) {
            return Err(format!("delta[{i}] = {d} outside [-{c}, {c}]"));
        }
    }
    let s: f64 = delta.iter().sum();
    if s.abs() > 1e-6 * c * (delta.len() as f64).max(1.0) {
        return Err(format!("sum delta = {s} != 0"));
    }
    Ok(())
}

/// The paper's *AdjustAlpha* step in δ-space: clip `delta` into
/// \[−C, C\] and spread the residual uniformly until Σᵢ δᵢ = `target`.
/// Implemented by shifting into u = δ + C ∈ \[0, 2C\] and reusing the
/// classification [`balance_to_target`] with unit labels. Returns `false`
/// when the target is unreachable inside the box.
pub fn balance_delta(delta: &mut [f64], c: f64, target: f64) -> bool {
    let n = delta.len();
    let mut u: Vec<f64> = delta.iter().map(|d| d + c).collect();
    let ones = vec![1.0f64; n];
    let ok = balance_to_target(&mut u, &ones, 2.0 * c, target + n as f64 * c);
    if ok {
        for (d, uu) in delta.iter_mut().zip(&u) {
            *d = (uu - c).clamp(-c, c);
        }
    }
    ok
}

// ---- shared helpers --------------------------------------------------------

/// Copy the shared instances' δ onto the next-round layout.
fn copy_shared(ctx: &SvrSeedContext) -> Vec<f64> {
    let mut delta = vec![0.0f64; ctx.next_train.len()];
    for (p, &gi) in ctx.prev_train.iter().enumerate() {
        if ctx.prev_delta[p] != 0.0 {
            if let Some(np) = pos_of(ctx.next_train, gi) {
                delta[np] = ctx.prev_delta[p];
            }
        }
    }
    delta
}

/// δ values of the removed instances, in `ctx.removed` order.
fn removed_deltas(ctx: &SvrSeedContext) -> Vec<f64> {
    ctx.removed
        .iter()
        .map(|&gr| {
            let p = pos_of(ctx.prev_train, gr).expect("R ⊄ prev_train");
            ctx.prev_delta[p]
        })
        .collect()
}

/// Repair Σδ = 0 preferring to move only the 𝒯 entries (they absorb the
/// transition, Eq. 16 analogue), falling back to a whole-vector balance,
/// then to the cold start.
fn finish_with_added_balance(ctx: &SvrSeedContext, mut delta: Vec<f64>) -> SvrSeedResult {
    let total: f64 = delta.iter().sum();
    if total.abs() <= 1e-9 {
        return SvrSeedResult {
            delta,
            fell_back: false,
        };
    }
    let t_positions: Vec<usize> = ctx
        .added
        .iter()
        .filter_map(|&gt| pos_of(ctx.next_train, gt))
        .collect();
    let mut t_delta: Vec<f64> = t_positions.iter().map(|&np| delta[np]).collect();
    let t_sum: f64 = t_delta.iter().sum();
    if !t_positions.is_empty() && balance_delta(&mut t_delta, ctx.c, t_sum - total) {
        for (&np, &d) in t_positions.iter().zip(&t_delta) {
            delta[np] = d;
        }
        return SvrSeedResult {
            delta,
            fell_back: false,
        };
    }
    finish_with_whole_balance(ctx, delta)
}

/// Repair Σδ = 0 over the whole vector; cold start when unreachable.
fn finish_with_whole_balance(ctx: &SvrSeedContext, mut delta: Vec<f64>) -> SvrSeedResult {
    let total: f64 = delta.iter().sum();
    if total.abs() <= 1e-9 {
        return SvrSeedResult {
            delta,
            fell_back: false,
        };
    }
    if balance_delta(&mut delta, ctx.c, 0.0) {
        SvrSeedResult {
            delta,
            fell_back: false,
        }
    } else {
        SvrSeedResult {
            delta: vec![0.0; ctx.next_train.len()],
            fell_back: true,
        }
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::FoldPlan;
    use crate::kernel::KernelEval;
    use crate::smo::problem::{collapse_svr_pairs, expand_svr_pairs, svr_errors, SvrProblem};
    use crate::smo::{GeneralSolver, QpProblem, SmoParams};

    /// Round-h solved state of an ε-SVR CV plan, ready to build contexts.
    pub struct SolvedSvrRound {
        pub full: Dataset,
        pub kernel: Kernel,
        pub c: f64,
        pub epsilon: f64,
        pub prev_train: Vec<usize>,
        pub prev_delta: Vec<f64>,
        pub prev_err: Vec<f64>,
        pub prev_b: f64,
        pub removed: Vec<usize>,
        pub added: Vec<usize>,
        pub next_train: Vec<usize>,
    }

    impl SolvedSvrRound {
        pub fn ctx(&self) -> SvrSeedContext<'_> {
            SvrSeedContext {
                full: &self.full,
                kernel: self.kernel,
                c: self.c,
                epsilon: self.epsilon,
                prev_train: &self.prev_train,
                prev_delta: &self.prev_delta,
                prev_err: &self.prev_err,
                prev_b: self.prev_b,
                removed: &self.removed,
                added: &self.added,
                next_train: &self.next_train,
                rng_seed: 7,
            }
        }

        pub fn cache(&self) -> KernelCache {
            KernelCache::with_byte_budget(
                KernelEval::new(self.full.clone(), self.kernel),
                64 << 20,
            )
        }

        /// Solve round h+1 from a δ seed; returns (iterations, obj, b).
        pub fn solve_next(&self, delta0: Vec<f64>) -> (u64, f64, f64) {
            let train = self.full.select(&self.next_train);
            let problem = SvrProblem {
                c: self.c,
                epsilon: self.epsilon,
            };
            let mut solver = GeneralSolver::new(
                KernelEval::new(train.clone(), self.kernel),
                problem.spec(&train),
                SmoParams::default(),
            );
            let r = solver.solve_from(expand_svr_pairs(&delta0), None);
            assert!(r.converged);
            (r.iterations, r.objective, r.b)
        }
    }

    /// Train round h=0 of a k-fold ε-SVR plan on a synthetic dataset.
    pub fn solved_svr_round(
        dataset: &str,
        n: usize,
        k: usize,
        c: f64,
        epsilon: f64,
        gamma: f64,
    ) -> SolvedSvrRound {
        let full = crate::data::synth::generate_regression(dataset, Some(n), 42);
        let kernel = Kernel::rbf(gamma);
        let plan = FoldPlan::random(full.len(), k, 11);
        let h = 0;
        let prev_train = plan.train_indices(h);
        let train = full.select(&prev_train);
        let problem = SvrProblem { c, epsilon };
        let mut solver = GeneralSolver::new(
            KernelEval::new(train.clone(), kernel),
            problem.spec(&train),
            SmoParams::default(),
        );
        let r = solver.solve();
        assert!(r.converged, "round-0 SVR solve did not converge");
        let t = plan.transition(h);
        SolvedSvrRound {
            full,
            kernel,
            c,
            epsilon,
            prev_train,
            prev_delta: collapse_svr_pairs(&r.alpha),
            prev_err: svr_errors(&r, epsilon),
            prev_b: r.b,
            removed: t.removed,
            added: t.added,
            next_train: plan.train_indices(h + 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::solved_svr_round;
    use super::*;

    #[test]
    fn all_seeders_emit_feasible_delta() {
        let sr = solved_svr_round("sinc", 120, 5, 10.0, 0.05, 0.5);
        for name in ALL_SVR_SEEDERS {
            let seeder = svr_seeder_by_name(name).unwrap();
            let mut cache = sr.cache();
            let r = seeder.seed(&sr.ctx(), &mut cache);
            check_feasible_delta(&r.delta, sr.c)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn sir_and_mir_keep_shared_delta() {
        let sr = solved_svr_round("sinc", 120, 5, 10.0, 0.05, 0.5);
        for name in ["sir", "mir"] {
            let seeder = svr_seeder_by_name(name).unwrap();
            let mut cache = sr.cache();
            let r = seeder.seed(&sr.ctx(), &mut cache);
            if r.fell_back {
                continue;
            }
            for (p, &gi) in sr.prev_train.iter().enumerate() {
                if sr.removed.contains(&gi) {
                    continue;
                }
                let np = sr.next_train.binary_search(&gi).unwrap();
                assert!(
                    (r.delta[np] - sr.prev_delta[p]).abs() < 1e-9,
                    "{name}: shared δ changed at {gi}"
                );
            }
        }
    }

    #[test]
    fn seeded_svr_reduces_iterations_and_preserves_objective() {
        let sr = solved_svr_round("sinc", 150, 5, 10.0, 0.05, 0.5);
        let mut cache = sr.cache();
        let cold = SvrCold.seed(&sr.ctx(), &mut cache);
        let (it_cold, obj_c, _) = sr.solve_next(cold.delta);
        for name in ["ato", "mir", "sir"] {
            let seeder = svr_seeder_by_name(name).unwrap();
            let seeded = seeder.seed(&sr.ctx(), &mut cache);
            assert!(!seeded.fell_back, "{name} fell back to cold start");
            let (it_seeded, obj_s, _) = sr.solve_next(seeded.delta);
            assert!(
                it_seeded < it_cold,
                "{name} did not reduce iterations: {it_seeded} vs cold {it_cold}"
            );
            assert!(
                (obj_s - obj_c).abs() < 1e-2 * obj_c.abs().max(1.0),
                "{name}: objective {obj_s} vs cold {obj_c}"
            );
        }
    }

    #[test]
    fn balance_delta_reaches_target_inside_box() {
        let mut d = vec![0.4, -0.2, 0.0];
        assert!(balance_delta(&mut d, 1.0, 0.0));
        assert!(d.iter().sum::<f64>().abs() < 1e-9);
        assert!(d.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        // unreachable: max sum is 3·C = 3 < 4
        let mut d = vec![0.0, 0.0, 0.0];
        assert!(!balance_delta(&mut d, 1.0, 4.0));
    }

    #[test]
    fn mir_degenerate_no_added() {
        // LOO-style transition (empty 𝒯): MIR rebalances the copy
        let sr = solved_svr_round("sinc", 80, 4, 5.0, 0.05, 0.5);
        let ctx_base = sr.ctx();
        let ctx = SvrSeedContext {
            added: &[],
            ..ctx_base
        };
        let mut cache = sr.cache();
        let r = SvrMir.seed(&ctx, &mut cache);
        check_feasible_delta(&r.delta, sr.c).unwrap();
    }
}
