//! Adjusting Alpha Towards Optimum (paper §3.1, Algorithm 1).
//!
//! The multi-incremental/decremental scheme of Karasuyama & Takeuchi
//! applied to the fold transition: ramp α_𝒯 up toward C and α_𝓡 down to 0
//! in steps of size η, compensating on the margin set 𝓜 so that both the
//! equality constraint (Eq. 8) and the margin-set optimality (Eq. 9) are
//! preserved. Each step solves the linear system of Eq. (10) for the
//! compensation Φ and picks the largest η that does not push any bounded
//! indicator past the bias (Eq. 11). Terminates when 𝓡 is drained.
//!
//! The paper notes (and Table 1 confirms) that ATO's initialisation is the
//! most expensive of the three — it exists as the "aim for the optimum"
//! upper bound. `max_steps` bounds the loop; on hitting the cap the
//! remaining 𝓡 mass is dropped and the Σyα balance repaired, exactly like
//! the MIR adjustment step.

use super::{balance_to_target, pos_of, SeedContext, SeedResult, Seeder};
use crate::kernel::KernelCache;
use crate::linalg::Mat;

/// Adjusting Alpha Towards Optimum.
#[derive(Debug, Clone, Copy)]
pub struct Ato {
    /// Hard cap on ramp steps (each step costs a least-squares solve plus
    /// O(|U|·(|𝓜|+|𝒯|+|𝓡|)) kernel lookups).
    pub max_steps: usize,
    /// Numerical floor below which an α is treated as drained to 0.
    pub drain_tol: f64,
    /// Cap on the compensation set 𝓜 fed to the Eq. (10) solve. The exact
    /// method is O(|𝓜|³) per step; capping keeps ATO the *slowest* seeder
    /// (the paper's qualitative finding) without letting a few thousand
    /// free SVs turn one fold transition into minutes. Instances beyond
    /// the cap simply don't compensate this step (the final balance pass
    /// repairs any drift). Deterministic: evenly-spaced selection.
    pub max_m: usize,
}

impl Default for Ato {
    fn default() -> Self {
        Ato {
            max_steps: 48,
            drain_tol: 1e-10,
            max_m: 256,
        }
    }
}

impl Seeder for Ato {
    fn name(&self) -> &'static str {
        "ato"
    }

    fn seed(&self, ctx: &SeedContext, cache: &mut KernelCache) -> SeedResult {
        let c = ctx.c;
        let y = &ctx.full.y;

        // Working state over the union U = prev_train ∪ added, addressed by
        // global index through position maps.
        let prev = ctx.prev_train;
        let added = ctx.added;
        let n_prev = prev.len();
        let n_t = added.len();

        // α aligned with prev (S ∪ R parts) and with added (𝒯 part).
        let mut a_prev: Vec<f64> = ctx.prev_alpha.to_vec();
        let mut a_t: Vec<f64> = vec![0.0; n_t];
        // f over prev from the solved SVM; f over 𝒯 computed fresh:
        // f_t = Σ_j α_j y_j K(t,j) − y_t  (sum over prev support vectors).
        let mut f_prev: Vec<f64> = ctx.prev_f.to_vec();
        let mut f_t: Vec<f64> = added.iter().map(|&gt| -y[gt]).collect();
        for (j, &gj) in prev.iter().enumerate() {
            if a_prev[j] > 0.0 {
                let coef = a_prev[j] * y[gj];
                let row = cache.row(gj);
                for (ti, &gt) in added.iter().enumerate() {
                    f_t[ti] += coef * row.get(gt);
                }
            }
        }

        // R positions within prev; is_removed mask.
        let r_pos: Vec<usize> = ctx
            .removed
            .iter()
            .map(|&gr| pos_of(prev, gr).expect("R ⊄ prev_train"))
            .collect();
        let mut is_removed = vec![false; n_prev];
        for &p in &r_pos {
            is_removed[p] = true;
        }

        let mut b = ctx.prev_b;
        let mut steps = 0usize;
        // 𝓜 changes rarely between steps; cache the pseudo-inverse of the
        // Eq. (10) system and reuse it while 𝓜 is stable (decomposition is
        // O(m³), a reused application only O(m²)).
        let mut cached_m: Vec<usize> = Vec::new();
        let mut cached_pinv: Option<Mat> = None;

        loop {
            // Active 𝓡: removed instances still carrying α.
            let r_active: Vec<usize> = r_pos
                .iter()
                .copied()
                .filter(|&p| a_prev[p] > self.drain_tol)
                .collect();
            if r_active.is_empty() || steps >= self.max_steps {
                break;
            }
            // Pending 𝒯: still ramping toward C... an added instance stops
            // ramping once its indicator satisfies Constraint (5).
            let t_pending: Vec<usize> = (0..n_t)
                .filter(|&ti| {
                    let a = a_t[ti];
                    if a >= c - self.drain_tol {
                        return false;
                    }
                    // satisfied when free and f ≈ b, or at 0 on the correct side
                    let f = f_t[ti];
                    let gt = added[ti];
                    let in_u = (y[gt] > 0.0 && a <= self.drain_tol) || (y[gt] < 0.0 && a >= c);
                    if a > self.drain_tol && (f - b).abs() < 1e-6 {
                        false
                    } else if in_u && f > b {
                        false
                    } else {
                        true
                    }
                })
                .collect();

            // 𝓜: free instances among the shared set (prev ∖ 𝓡), capped
            // to max_m by even-stride subsampling (see field doc).
            let mut m_set: Vec<usize> = (0..n_prev)
                .filter(|&p| !is_removed[p] && a_prev[p] > self.drain_tol && a_prev[p] < c - self.drain_tol)
                .collect();
            if m_set.len() > self.max_m {
                let stride = m_set.len() as f64 / self.max_m as f64;
                m_set = (0..self.max_m)
                    .map(|i| m_set[(i as f64 * stride) as usize])
                    .collect();
            }
            let m = m_set.len();

            // Ramp directions: u_T = C·1 − α_T (pending only), u_R = −α_R.
            let u_t: Vec<f64> = t_pending.iter().map(|&ti| c - a_t[ti]).collect();
            let u_r: Vec<f64> = r_active.iter().map(|&p| -a_prev[p]).collect();

            // Φ from Eq. (10): [y_M; Q_MM]·Φ = [y_T y_R; Q_MT Q_MR]·[u_T; u_R]
            let phi: Vec<f64> = if m > 0 {
                let mut rhs = vec![0.0f64; m + 1];
                // first row: y_T·u_T + y_R·u_R
                for (k, &ti) in t_pending.iter().enumerate() {
                    rhs[0] += y[added[ti]] * u_t[k];
                }
                for (k, &p) in r_active.iter().enumerate() {
                    rhs[0] += y[prev[p]] * u_r[k];
                }
                // remaining rows: Q_{M,T}·u_T + Q_{M,R}·u_R
                for (k, &ti) in t_pending.iter().enumerate() {
                    let gt = added[ti];
                    let coef = u_t[k] * y[gt];
                    let row = cache.row(gt);
                    for (mi, &p) in m_set.iter().enumerate() {
                        let gp = prev[p];
                        rhs[mi + 1] += y[gp] * coef * row.get(gp);
                    }
                }
                for (k, &p) in r_active.iter().enumerate() {
                    let gr = prev[p];
                    let coef = u_r[k] * y[gr];
                    let row = cache.row(gr);
                    for (mi, &pm) in m_set.iter().enumerate() {
                        let gm = prev[pm];
                        rhs[mi + 1] += y[gm] * coef * row.get(gm);
                    }
                }
                if cached_pinv.is_none() || cached_m != m_set {
                    let mut bmat = Mat::zeros(m + 1, m);
                    for (mj, &pj) in m_set.iter().enumerate() {
                        let gj = prev[pj];
                        bmat[(0, mj)] = y[gj];
                        let row = cache.row(gj);
                        for (mi, &pi) in m_set.iter().enumerate() {
                            let gi = prev[pi];
                            bmat[(mi + 1, mj)] = y[gi] * y[gj] * row.get(gi);
                        }
                    }
                    cached_pinv = Some(bmat.pinv());
                    cached_m = m_set.clone();
                }
                cached_pinv.as_ref().unwrap().matvec(&rhs)
            } else {
                Vec::new()
            };

            // Unit indicator change w (Eq. 11, divided by η):
            // y ⊙ Δf/η = −Q_{·,M}·Φ + Q_{·,T}·u_T + Q_{·,R}·u_R  over U.
            let mut w_prev = vec![0.0f64; n_prev];
            let mut w_t = vec![0.0f64; n_t];
            let accumulate = |coef: f64, g_src: usize,
                                   w_prev: &mut [f64],
                                   w_t: &mut [f64],
                                   cache: &mut KernelCache| {
                let row = cache.row(g_src);
                for (i, &gi) in prev.iter().enumerate() {
                    w_prev[i] += y[gi] * coef * row.get(gi);
                }
                for (ti, &gt) in added.iter().enumerate() {
                    w_t[ti] += y[gt] * coef * row.get(gt);
                }
            };
            for (mj, &pj) in m_set.iter().enumerate() {
                let gj = prev[pj];
                accumulate(-phi[mj] * y[gj], gj, &mut w_prev, &mut w_t, cache);
            }
            for (k, &ti) in t_pending.iter().enumerate() {
                let gt = added[ti];
                accumulate(u_t[k] * y[gt], gt, &mut w_prev, &mut w_t, cache);
            }
            for (k, &p) in r_active.iter().enumerate() {
                let gr = prev[p];
                accumulate(u_r[k] * y[gr], gr, &mut w_prev, &mut w_t, cache);
            }
            // Δfᵢ/η = yᵢ·wᵢ (y ⊙ Δf = w, y² = 1)
            for (i, &gi) in prev.iter().enumerate() {
                w_prev[i] *= y[gi];
            }
            for (ti, &gt) in added.iter().enumerate() {
                w_t[ti] *= y[gt];
            }

            // Step size: largest η ≤ 1 such that no bounded indicator
            // crosses b (fᵢ + η·wᵢ = b ⇒ η = (b − fᵢ)/wᵢ, positive only).
            let mut eta = 1.0f64;
            for (i, &gi) in prev.iter().enumerate() {
                if is_removed[i] {
                    continue;
                }
                let a = a_prev[i];
                let free = a > self.drain_tol && a < c - self.drain_tol;
                if free {
                    continue; // margin set is held at f = b by Φ
                }
                let gap = b - f_prev[i];
                if w_prev[i].abs() > 1e-14 {
                    let cand = gap / w_prev[i];
                    if cand > 1e-12 && cand < eta {
                        // only binding if the move is toward b
                        let _ = gi;
                        eta = cand;
                    }
                }
            }
            if eta <= 1e-12 {
                eta = 1e-3; // numerical stall guard: take a small fixed step
            }

            // Apply the step.
            for (mj, &pj) in m_set.iter().enumerate() {
                a_prev[pj] = (a_prev[pj] - eta * phi[mj]).clamp(0.0, c);
            }
            for (k, &ti) in t_pending.iter().enumerate() {
                a_t[ti] = (a_t[ti] + eta * u_t[k]).clamp(0.0, c);
            }
            for (k, &p) in r_active.iter().enumerate() {
                a_prev[p] = (a_prev[p] + eta * u_r[k]).max(0.0);
            }
            for i in 0..n_prev {
                f_prev[i] += eta * w_prev[i];
            }
            for ti in 0..n_t {
                f_t[ti] += eta * w_t[ti];
            }
            // Fully drain 𝓡 entries that are numerically zero.
            for &p in &r_pos {
                if a_prev[p] <= self.drain_tol {
                    a_prev[p] = 0.0;
                }
            }
            // Refresh b as the mean indicator over the current margin set.
            let m_now: Vec<usize> = (0..n_prev)
                .filter(|&p| {
                    !is_removed[p] && a_prev[p] > self.drain_tol && a_prev[p] < c - self.drain_tol
                })
                .collect();
            if !m_now.is_empty() {
                b = m_now.iter().map(|&p| f_prev[p]).sum::<f64>() / m_now.len() as f64;
            }
            steps += 1;
        }

        // Assemble the seed over next_train: shared α (possibly adjusted
        // through 𝓜) plus the ramped α_𝒯. Any α still on 𝓡 is dropped.
        let next = ctx.next_train;
        let mut alpha = vec![0.0f64; next.len()];
        for (p, &gi) in prev.iter().enumerate() {
            if is_removed[p] {
                continue;
            }
            if let Some(np) = pos_of(next, gi) {
                alpha[np] = a_prev[p];
            }
        }
        for (ti, &gt) in added.iter().enumerate() {
            if let Some(np) = pos_of(next, gt) {
                alpha[np] = a_t[ti];
            }
        }

        // Feasibility repair: clipping + dropped-𝓡 residue can leave
        // Σyα ≠ 0; rebalance over 𝒯 first (it absorbs the transition),
        // falling back to a whole-vector balance, then cold start.
        let ny: Vec<f64> = next.iter().map(|&gi| y[gi]).collect();
        let total: f64 = alpha.iter().zip(&ny).map(|(a, yy)| a * yy).sum();
        let mut fell_back = false;
        if total.abs() > 1e-9 {
            let t_positions: Vec<usize> = added
                .iter()
                .filter_map(|&gt| pos_of(next, gt))
                .collect();
            let mut t_alpha: Vec<f64> = t_positions.iter().map(|&np| alpha[np]).collect();
            let t_y: Vec<f64> = t_positions.iter().map(|&np| ny[np]).collect();
            let t_sum: f64 = t_alpha.iter().zip(&t_y).map(|(a, yy)| a * yy).sum();
            if balance_to_target(&mut t_alpha, &t_y, c, t_sum - total) {
                for (&np, &a) in t_positions.iter().zip(&t_alpha) {
                    alpha[np] = a;
                }
            } else if !balance_to_target(&mut alpha, &ny, c, 0.0) {
                alpha.iter_mut().for_each(|a| *a = 0.0);
                fell_back = true;
            }
        }

        SeedResult { alpha, fell_back }
    }

    fn seed_active_set(
        &self,
        ctx: &SeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        // ATO's ramp may move shared α through the margin set, so the
        // carried guess is coarser than SIR/MIR's — but the solver only
        // accepts positions that are bounded *at the seeded α* and
        // non-violating under the fresh gradient, so over-proposing here
        // is harmless.
        Some(super::carry_bounded_positions(
            ctx.prev_train,
            prev_partition,
            ctx.next_train,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::test_support::solved_round;
    use crate::seeding::{check_feasible, ColdStart, Seeder};

    #[test]
    fn seed_is_feasible() {
        let sr = solved_round("heart", 100, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let r = Ato::default().seed(&sr.ctx(), &mut cache);
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        check_feasible(&r.alpha, &y, sr.c).unwrap();
    }

    #[test]
    fn drains_removed_set() {
        let sr = solved_round("heart", 100, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let r = Ato::default().seed(&sr.ctx(), &mut cache);
        // removed instances are not in next_train, so by construction the
        // seed carries no α for them; verify 𝒯 received some mass when 𝓡
        // had support vectors (the ramp actually ran).
        let removed_mass: f64 = sr
            .removed
            .iter()
            .map(|&gr| sr.prev_alpha[sr.prev_train.binary_search(&gr).unwrap()])
            .sum();
        if removed_mass > 1e-6 && !r.fell_back {
            let t_mass: f64 = sr
                .added
                .iter()
                .filter_map(|&gt| sr.next_train.binary_search(&gt).ok())
                .map(|np| r.alpha[np])
                .sum();
            assert!(t_mass > 0.0, "ramp moved no mass into 𝒯");
        }
    }

    #[test]
    fn reduces_iterations_vs_cold() {
        let sr = solved_round("heart", 150, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let seeded = Ato::default().seed(&sr.ctx(), &mut cache);
        let cold = ColdStart.seed(&sr.ctx(), &mut cache);
        let (it_seeded, obj_s, _) = sr.solve_next(seeded.alpha);
        let (it_cold, obj_c, _) = sr.solve_next(cold.alpha);
        assert!(
            it_seeded < it_cold,
            "ATO did not reduce iterations: {it_seeded} vs cold {it_cold}"
        );
        assert!((obj_s - obj_c).abs() < 1e-3 * obj_c.abs().max(1.0));
    }

    #[test]
    fn respects_step_cap() {
        let sr = solved_round("heart", 80, 4, 2.0, 0.2);
        let mut cache = sr.cache();
        let ato = Ato {
            max_steps: 1,
            ..Default::default()
        };
        let r = ato.seed(&sr.ctx(), &mut cache);
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        // even with the cap the emitted seed must be feasible
        check_feasible(&r.alpha, &y, sr.c).unwrap();
    }
}
