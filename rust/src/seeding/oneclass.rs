//! Alpha seeding for the one-class SVM fold chain.
//!
//! The one-class dual has the box 0 ≤ αᵢ ≤ 1 and the equality constraint
//! Σᵢ αᵢ = ν·n — like C-SVC with every "label" +1 and a **round-dependent
//! right-hand side** (n changes with the training-fold size). The SIR
//! transplant rule carries over directly: copy the shared α, move each
//! removed support weight onto the most similar entering instance, then
//! repair the sum to the new ν·n with the *AdjustAlpha* pass (which also
//! absorbs the between-round change of ν·n itself). Cold start is the
//! LibSVM ν-fraction point, not α = 0
//! ([`oneclass_initial_alpha`](crate::smo::problem::oneclass_initial_alpha)).

use super::pos_of;
use crate::data::Dataset;
use crate::kernel::{Kernel, KernelCache};
use crate::seeding::balance_to_target;
use crate::smo::problem::oneclass_initial_alpha;

/// Everything the one-class seeder may use from round h to initialise
/// round h+1. Index slices hold global indices into `full`.
pub struct OneClassSeedContext<'a> {
    /// The complete dataset (all k folds; labels are evaluation-only).
    pub full: &'a Dataset,
    /// The kernel both rounds train with.
    pub kernel: Kernel,
    /// ν ∈ (0, 1]; fixes the per-round constraint Σα = ν·|train|.
    pub nu: f64,
    /// Round h's training instances.
    pub prev_train: &'a [usize],
    /// Round h's optimal α, aligned with `prev_train`.
    pub prev_alpha: &'a [f64],
    /// 𝓡: leaving the training set (fold h+1).
    pub removed: &'a [usize],
    /// 𝒯: entering the training set (fold h).
    pub added: &'a [usize],
    /// Round h+1's training instances (sorted).
    pub next_train: &'a [usize],
}

/// Outcome of a one-class seeding step.
#[derive(Debug, Clone)]
pub struct OneClassSeedResult {
    /// Initial α aligned with `ctx.next_train`: 0 ≤ αᵢ ≤ 1 and
    /// Σᵢ αᵢ = ν·|next_train|.
    pub alpha: Vec<f64>,
    /// True if the transplant could not reach the constraint and the
    /// LibSVM ν-fraction cold start was used instead.
    pub fell_back: bool,
}

/// SIR-style transplant for the one-class chain: copy shared α, move each
/// removed αₚ > 0 (largest first) onto the most similar unused 𝒯
/// instance (one cached kernel row per removed support vector), then
/// balance Σα to ν·|next| inside the unit box.
pub fn seed_oneclass(ctx: &OneClassSeedContext, cache: &mut KernelCache) -> OneClassSeedResult {
    let next = ctx.next_train;
    let n_next = next.len();
    let target = ctx.nu * n_next as f64;

    let mut alpha = vec![0.0f64; n_next];
    for (p, &gi) in ctx.prev_train.iter().enumerate() {
        if ctx.prev_alpha[p] > 0.0 {
            if let Some(np) = pos_of(next, gi) {
                alpha[np] = ctx.prev_alpha[p];
            }
        }
    }

    // Transplant removed weights, largest first (shared greedy loop;
    // α ≥ 0 here, so |weight| ordering is plain descending α).
    let r_alpha: Vec<f64> = ctx
        .removed
        .iter()
        .map(|&gr| {
            let p = pos_of(ctx.prev_train, gr).expect("R ⊄ prev_train");
            ctx.prev_alpha[p]
        })
        .collect();
    super::transplant_by_similarity(
        ctx.removed,
        &r_alpha,
        ctx.added,
        next,
        cache,
        |np, w| alpha[np] = w,
    );

    // Σα must equal ν·|next| (a different value than round h's when fold
    // sizes differ); AdjustAlpha with unit labels repairs both the
    // transplant residue and that shift.
    let ones = vec![1.0f64; n_next];
    if balance_to_target(&mut alpha, &ones, 1.0, target) {
        OneClassSeedResult {
            alpha,
            fell_back: false,
        }
    } else {
        OneClassSeedResult {
            alpha: oneclass_initial_alpha(ctx.nu, n_next),
            fell_back: true,
        }
    }
}

/// Validate a one-class seed: unit box and Σα = ν·n.
pub fn check_feasible_oneclass(alpha: &[f64], nu: f64) -> Result<(), String> {
    for (i, &a) in alpha.iter().enumerate() {
        if !(-1e-9..=1.0 + 1e-9).contains(&a) {
            return Err(format!("alpha[{i}] = {a} outside [0, 1]"));
        }
    }
    let target = nu * alpha.len() as f64;
    let s: f64 = alpha.iter().sum();
    if (s - target).abs() > 1e-6 * (alpha.len() as f64).max(1.0) {
        return Err(format!("sum alpha = {s} != nu*n = {target}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::FoldPlan;
    use crate::kernel::KernelEval;
    use crate::smo::problem::{solver_for, OneClassProblem};
    use crate::smo::{QpProblem, SmoParams};

    fn solved_round() -> (Dataset, Kernel, f64, Vec<usize>, Vec<f64>, FoldPlan) {
        let full = crate::data::synth::generate_outliers(Some(150), 0.1, 3);
        let kernel = Kernel::rbf(1.0);
        let nu = 0.2;
        let plan = FoldPlan::stratified(&full, 5, 11);
        let prev_train = plan.train_indices(0);
        let train = full.select(&prev_train);
        let problem = OneClassProblem { nu };
        let mut solver = solver_for(&problem, &train, kernel, SmoParams::default());
        let beta0 = problem.initial_alpha(&train);
        let r = solver.solve_from(beta0, None);
        assert!(r.converged);
        (full, kernel, nu, prev_train, r.alpha, plan)
    }

    #[test]
    fn transplant_seed_is_feasible() {
        let (full, kernel, nu, prev_train, prev_alpha, plan) = solved_round();
        let t = plan.transition(0);
        let next_train = plan.train_indices(1);
        let ctx = OneClassSeedContext {
            full: &full,
            kernel,
            nu,
            prev_train: &prev_train,
            prev_alpha: &prev_alpha,
            removed: &t.removed,
            added: &t.added,
            next_train: &next_train,
        };
        let mut cache =
            KernelCache::with_byte_budget(KernelEval::new(full.clone(), kernel), 16 << 20);
        let r = seed_oneclass(&ctx, &mut cache);
        check_feasible_oneclass(&r.alpha, nu).unwrap();
    }

    #[test]
    fn transplant_seed_reduces_iterations() {
        let (full, kernel, nu, prev_train, prev_alpha, plan) = solved_round();
        let t = plan.transition(0);
        let next_train = plan.train_indices(1);
        let train1 = full.select(&next_train);
        let problem = OneClassProblem { nu };

        let solve_from = |alpha0: Vec<f64>| {
            let mut solver = solver_for(&problem, &train1, kernel, SmoParams::default());
            let r = solver.solve_from(alpha0, None);
            assert!(r.converged);
            r
        };
        let cold = solve_from(problem.initial_alpha(&train1));

        let ctx = OneClassSeedContext {
            full: &full,
            kernel,
            nu,
            prev_train: &prev_train,
            prev_alpha: &prev_alpha,
            removed: &t.removed,
            added: &t.added,
            next_train: &next_train,
        };
        let mut cache =
            KernelCache::with_byte_budget(KernelEval::new(full.clone(), kernel), 16 << 20);
        let seed = seed_oneclass(&ctx, &mut cache);
        assert!(!seed.fell_back);
        let warm = solve_from(seed.alpha);
        assert!(
            warm.iterations < cold.iterations,
            "transplant {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!(
            (warm.objective - cold.objective).abs() < 1e-2 * cold.objective.abs().max(1.0),
            "objective {} vs {}",
            warm.objective,
            cold.objective
        );
    }

    #[test]
    fn feasibility_checker_catches_violations() {
        assert!(check_feasible_oneclass(&[0.5, 0.5], 0.5).is_ok());
        assert!(check_feasible_oneclass(&[1.5, 0.0], 0.75).is_err()); // box
        assert!(check_feasible_oneclass(&[0.5, 0.5], 0.2).is_err()); // sum
    }
}
