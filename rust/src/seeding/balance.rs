//! The paper's *AdjustAlpha* step, shared by MIR and SIR.
//!
//! After estimating α'_𝒯, the constraints 0 ≤ α'_t ≤ C and
//! Σ_t y_t·α'_t = Σ_r y_r·α_r may be violated. The paper prescribes:
//! clip into the box, then *uniformly* increase/decrease the y_t·α'_t
//! until the signed sum matches the target, re-distributing the residual
//! over the entries that can still move.

/// Adjust `alpha` (box [0, c]) so that Σᵢ yᵢ·αᵢ == `target`.
///
/// Works in s = y·α space, where the box maps to [0, c] for y = +1 and
/// [−c, 0] for y = −1. Each pass spreads the residual equally over every
/// entry with remaining headroom; entries that saturate absorb what they
/// can and the loop re-distributes the rest (exactly the paper's scheme
/// for AVG overflow, applied to the 𝒯 set).
///
/// Returns `false` when the target is unreachable within the box (the
/// caller falls back to the cold start).
pub fn balance_to_target(alpha: &mut [f64], y: &[f64], c: f64, target: f64) -> bool {
    assert_eq!(alpha.len(), y.len());
    // Clip into the box first (paper step 1).
    for a in alpha.iter_mut() {
        *a = a.clamp(0.0, c);
    }
    let mut sum: f64 = alpha.iter().zip(y).map(|(a, yy)| a * yy).sum();
    let tol = 1e-12 * c.max(1.0) * (alpha.len() as f64).max(1.0);

    for _pass in 0..64 {
        let delta = target - sum;
        if delta.abs() <= tol {
            return true;
        }
        // Headroom of entry i in s-space, in the direction of delta:
        // s_i = y_i·α_i ∈ [min_i, max_i].
        fn headroom(alpha: &[f64], y: &[f64], c: f64, delta: f64, i: usize) -> f64 {
            let s = y[i] * alpha[i];
            if delta > 0.0 {
                let max = if y[i] > 0.0 { c } else { 0.0 };
                max - s
            } else {
                let min = if y[i] > 0.0 { 0.0 } else { -c };
                s - min
            }
        }
        let movable: Vec<usize> = (0..alpha.len())
            .filter(|&i| headroom(alpha, y, c, delta, i) > tol)
            .collect();
        if movable.is_empty() {
            return false;
        }
        let step = delta / movable.len() as f64;
        for &i in &movable {
            let room = headroom(alpha, y, c, delta, i);
            let move_by = step.abs().min(room) * step.signum();
            // s_i += move_by  →  α_i += y_i·move_by
            alpha[i] += y[i] * move_by;
            alpha[i] = alpha[i].clamp(0.0, c);
            sum += move_by;
        }
    }
    (target - sum).abs() <= tol.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn signed_sum(alpha: &[f64], y: &[f64]) -> f64 {
        alpha.iter().zip(y).map(|(a, yy)| a * yy).sum()
    }

    #[test]
    fn already_balanced_is_noop() {
        let mut a = vec![0.5, 0.5];
        let y = vec![1.0, -1.0];
        assert!(balance_to_target(&mut a, &y, 1.0, 0.0));
        assert_eq!(a, vec![0.5, 0.5]);
    }

    #[test]
    fn uniform_increase() {
        let mut a = vec![0.0, 0.0, 0.0];
        let y = vec![1.0, 1.0, 1.0];
        assert!(balance_to_target(&mut a, &y, 1.0, 1.5));
        assert!((signed_sum(&a, &y) - 1.5).abs() < 1e-9);
        for &x in &a {
            assert!((x - 0.5).abs() < 1e-9, "uniform spread expected: {a:?}");
        }
    }

    #[test]
    fn saturation_redistributes() {
        // first entry can only take 0.2 more; rest spills to the others
        let mut a = vec![0.8, 0.0, 0.0];
        let y = vec![1.0, 1.0, 1.0];
        assert!(balance_to_target(&mut a, &y, 1.0, 2.0));
        assert!((signed_sum(&a, &y) - 2.0).abs() < 1e-9);
        // pass 1 spreads 0.4 each (entry 0 clamps at 1.0, absorbing 0.2);
        // pass 2 spreads the leftover 0.2 over the two still-movable slots
        assert!((a[0] - 1.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 0.5).abs() < 1e-6, "{a:?}");
        assert!((a[2] - 0.5).abs() < 1e-6, "{a:?}");
    }

    #[test]
    fn mixed_labels() {
        let mut a = vec![0.3, 0.3];
        let y = vec![1.0, -1.0];
        // current sum = 0; push to −0.4: positive entry shrinks / negative grows
        assert!(balance_to_target(&mut a, &y, 1.0, -0.4));
        assert!((signed_sum(&a, &y) + 0.4).abs() < 1e-9);
        for &x in &a {
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn clips_out_of_box_input() {
        let mut a = vec![1.7, -0.3];
        let y = vec![1.0, -1.0];
        assert!(balance_to_target(&mut a, &y, 1.0, 0.5));
        assert!((signed_sum(&a, &y) - 0.5).abs() < 1e-9);
        for &x in &a {
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn unreachable_target_reports_false() {
        let mut a = vec![0.0, 0.0];
        let y = vec![1.0, 1.0];
        // max achievable sum = 2·c = 2 < 3
        assert!(!balance_to_target(&mut a, &y, 1.0, 3.0));
    }

    #[test]
    fn decrease_path() {
        let mut a = vec![1.0, 1.0, 0.5];
        let y = vec![1.0, 1.0, 1.0];
        assert!(balance_to_target(&mut a, &y, 1.0, 1.0));
        assert!((signed_sum(&a, &y) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_slice_only_balances_zero_target() {
        let mut a: Vec<f64> = vec![];
        let y: Vec<f64> = vec![];
        assert!(balance_to_target(&mut a, &y, 1.0, 0.0));
        assert!(!balance_to_target(&mut a, &y, 1.0, 0.5));
    }
}
