//! The baseline: no reuse, α = 0 — exactly what LibSVM's
//! `svm_cross_validation` does for every fold.

use super::{SeedContext, SeedResult, Seeder};
use crate::kernel::KernelCache;

/// Cold start (the paper's "LibSVM" column).
#[derive(Debug, Default, Clone, Copy)]
pub struct ColdStart;

impl Seeder for ColdStart {
    fn name(&self) -> &'static str {
        "cold"
    }

    fn seed(&self, ctx: &SeedContext, _cache: &mut KernelCache) -> SeedResult {
        SeedResult {
            alpha: vec![0.0; ctx.next_train.len()],
            fell_back: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::test_support::solved_round;

    #[test]
    fn emits_zeros_of_right_length() {
        let sr = solved_round("heart", 80, 4, 2.0, 0.2);
        let r = ColdStart.seed(&sr.ctx(), &mut sr.cache());
        assert_eq!(r.alpha.len(), sr.next_train.len());
        assert!(r.alpha.iter().all(|&a| a == 0.0));
        assert!(!r.fell_back);
    }
}
