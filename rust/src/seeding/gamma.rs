//! Cross-γ alpha transfer — seeding a grid cell's first round from the
//! adjacent γ's solved model (docs/SEEDING.md §8).
//!
//! The paper seeds across *folds* (the training sets overlap by (k−2)/k),
//! warm-C chains seed across *C* (same training set, rescaled box). A γ
//! step is the remaining cold edge in a (C, γ) grid: the training set is
//! unchanged — fold partitions depend only on (n, k, seed), never on the
//! hyper-parameters — and only the kernel matrix moves. For nearby γ the
//! RBF matrices are close, so the previous γ's optimum is a good starting
//! point for the new QP. The dual constraints do not mention γ at all, so
//! the transfer reduces to the same clip-and-rebalance feasibility
//! machinery as the fold transfer:
//!
//! * **C-SVC** ([`project_alpha_csvc`]): clip the donor α into \[0, C\],
//!   then restore Σyᵢαᵢ = 0 with [`balance_to_target`] (the paper's
//!   AdjustAlpha). When donor and recipient share C — the grid's cross-γ
//!   edge always does — the clip is a no-op and the balance only absorbs
//!   solver round-off; the general form also projects across a C change.
//! * **ε-SVR** ([`project_delta_svr`]): identical in δ = α − α* space —
//!   clip into \[−C, C\], restore Σδ = 0 via [`balance_delta`]'s
//!   u = δ + C shift.
//!
//! Both return `None` when the balance pass cannot reach the equality
//! target inside the box (possible only when projecting onto a much
//! smaller C); callers then fall back to a cold start. Like every seeding
//! transfer in this crate, the projection moves the solver's *starting
//! point*, never its fixed point: the recipient cell's converged model —
//! and therefore its CV accuracy/MSE — is unchanged, only iteration
//! counts move (pinned by `tests/budget_grid.rs`).
#![deny(missing_docs)]

use super::balance_to_target;
use super::svr::balance_delta;

/// Project a solved C-SVC α from an adjacent-γ cell onto the recipient
/// cell's feasible set: clip into `[0, c]`, then rebalance Σyᵢαᵢ back to
/// 0 over the entries with box headroom.
///
/// `prev_alpha` and `y` are aligned with the (shared) training set of the
/// round being seeded. Returns `None` when the equality target is
/// unreachable inside the box — the caller starts cold.
pub fn project_alpha_csvc(prev_alpha: &[f64], y: &[f64], c: f64) -> Option<Vec<f64>> {
    debug_assert_eq!(prev_alpha.len(), y.len());
    let mut out: Vec<f64> = prev_alpha.iter().map(|&a| a.clamp(0.0, c)).collect();
    if balance_to_target(&mut out, y, c, 0.0) {
        Some(out)
    } else {
        None
    }
}

/// Project solved ε-SVR pair differences δ = α − α* from an adjacent-γ
/// cell onto the recipient's feasible set: clip into `[-c, c]`, then
/// rebalance Σδ back to 0.
///
/// Returns `None` when the equality target is unreachable inside the box
/// — the caller starts cold.
pub fn project_delta_svr(prev_delta: &[f64], c: f64) -> Option<Vec<f64>> {
    let mut out: Vec<f64> = prev_delta.iter().map(|&d| d.clamp(-c, c)).collect();
    if balance_delta(&mut out, c, 0.0) {
        Some(out)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::check_feasible;
    use crate::seeding::svr::check_feasible_delta;

    #[test]
    fn same_c_projection_is_identity_up_to_roundoff() {
        // A feasible donor with the same C projects to itself.
        let y = [1.0, -1.0, 1.0, -1.0];
        let alpha = [0.5, 0.5, 1.25, 1.25];
        let p = project_alpha_csvc(&alpha, &y, 2.0).expect("feasible donor");
        assert_eq!(p, alpha.to_vec());
        check_feasible(&p, &y, 2.0).unwrap();
    }

    #[test]
    fn shrinking_c_clips_and_rebalances() {
        let y = [1.0, -1.0, 1.0, -1.0];
        // Feasible at C=4; entries above the new C=1 box must clip and
        // the y-weighted sum must be restored on the remaining headroom.
        let alpha = [4.0, 3.0, 0.0, 1.0];
        let p = project_alpha_csvc(&alpha, &y, 1.0).expect("target reachable");
        for &a in &p {
            assert!((0.0..=1.0 + 1e-12).contains(&a));
        }
        check_feasible(&p, &y, 1.0).unwrap();
    }

    #[test]
    fn degenerate_single_label_donor_stays_feasible() {
        // All-positive labels force the balance pass to drain everything
        // back to α = 0 (the only point with Σyα = 0); whatever the
        // projection returns must satisfy the contract.
        let y = [1.0, 1.0];
        let alpha = [3.0, 3.0];
        if let Some(p) = project_alpha_csvc(&alpha, &y, 0.5) {
            check_feasible(&p, &y, 0.5).unwrap();
        }
    }

    #[test]
    fn svr_projection_restores_pair_feasibility() {
        let delta = [2.0, -1.5, 0.25, -0.25];
        let p = project_delta_svr(&delta, 1.0).expect("target reachable");
        check_feasible_delta(&p, 1.0).unwrap();
        // Entries inside the box that the balance pass did not need stay
        // put: the projection is minimal, not a re-solve.
        assert!(p.iter().all(|&d| (-1.0..=1.0).contains(&d)));
    }
}
