//! TOP (Lee et al. 2004) — leave-one-out baseline.
//!
//! Instead of spreading the removed weight uniformly (AVG), give it to the
//! instances *most similar* to x_t: sort the survivors by kernel value
//! K(x_j, x_t) descending and pour y_t·α_t into them one by one, each
//! absorbing as much as its box constraint allows (paper supplementary
//! §TOP). Same LOO context contract as [`super::Avg`].

use super::{pos_of, SeedContext, SeedResult, Seeder};
use crate::kernel::KernelCache;

/// Similarity-ranked redistribution.
#[derive(Debug, Default, Clone, Copy)]
pub struct Top;

impl Seeder for Top {
    fn name(&self) -> &'static str {
        "top"
    }

    fn seed(&self, ctx: &SeedContext, cache: &mut KernelCache) -> SeedResult {
        assert!(
            ctx.added.is_empty(),
            "TOP is a leave-one-out seeder: 𝒯 must be empty"
        );
        let c = ctx.c;
        let y = &ctx.full.y;
        let next = ctx.next_train;

        let mut alpha = vec![0.0f64; next.len()];
        for (p, &gi) in ctx.prev_train.iter().enumerate() {
            if let Some(np) = pos_of(next, gi) {
                alpha[np] = ctx.prev_alpha[p];
            }
        }

        for &gt in ctx.removed {
            let p = pos_of(ctx.prev_train, gt).expect("R ⊄ prev_train");
            let at = ctx.prev_alpha[p];
            if at <= 0.0 {
                continue;
            }
            let yt = y[gt];
            // Rank survivors by similarity to the removed instance.
            let row_t = cache.row(gt);
            let mut order: Vec<usize> = (0..next.len()).collect();
            order.sort_by(|&a, &b| {
                row_t.get(next[b])
                    .partial_cmp(&row_t.get(next[a]))
                    .unwrap_or(std::cmp::Ordering::Equal)
            });

            // Pour y_t·α_t down the ranking.
            let mut remaining = at; // in α units; sign handled per target
            for &j in &order {
                if remaining <= 1e-15 {
                    break;
                }
                let yj = y[next[j]];
                if yj == yt {
                    // same label: increase α_j toward C
                    let room = c - alpha[j];
                    let give = remaining.min(room);
                    alpha[j] += give;
                    remaining -= give;
                } else {
                    // opposite label: decrease α_j toward 0
                    let room = alpha[j];
                    let give = remaining.min(room);
                    alpha[j] -= give;
                    remaining -= give;
                }
            }
            if remaining > 1e-9 {
                // Could not place the full weight (box saturated): repair
                // globally like the other seeders.
                let ny: Vec<f64> = next.iter().map(|&gi| y[gi]).collect();
                if !super::balance_to_target(&mut alpha, &ny, c, 0.0) {
                    return SeedResult {
                        alpha: vec![0.0; next.len()],
                        fell_back: true,
                    };
                }
            }
        }

        SeedResult {
            alpha,
            fell_back: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::FoldPlan;
    use crate::kernel::{Kernel, KernelEval};
    use crate::seeding::check_feasible;
    use crate::smo::{SmoParams, Solver};

    #[test]
    fn loo_seed_feasible_and_warm() {
        let n = 80;
        let full = crate::data::synth::generate("heart", Some(n), 33);
        let kernel = Kernel::rbf(0.2);
        let mut solver =
            Solver::new(KernelEval::new(full.clone(), kernel), SmoParams::with_c(2.0));
        let r = solver.solve();
        let f = r.f_indicators(&full.y);
        let prev_train: Vec<usize> = (0..n).collect();
        let t = 5usize;
        let plan = FoldPlan::leave_one_out(n);
        let next_train = plan.train_indices(t);
        let ctx = SeedContext {
            full: &full,
            kernel,
            c: 2.0,
            prev_train: &prev_train,
            prev_alpha: &r.alpha,
            prev_f: &f,
            prev_b: r.b,
            removed: &[t],
            added: &[],
            next_train: &next_train,
            rng_seed: 1,
        };
        let mut cache =
            KernelCache::with_byte_budget(KernelEval::new(full.clone(), kernel), 16 << 20);
        let seed = Top.seed(&ctx, &mut cache);
        let y: Vec<f64> = next_train.iter().map(|&i| full.y[i]).collect();
        check_feasible(&seed.alpha, &y, 2.0).unwrap();

        let train = full.select(&next_train);
        let mut s_warm = Solver::new(
            KernelEval::new(train.clone(), kernel),
            SmoParams::with_c(2.0),
        );
        let rw = s_warm.solve_from(seed.alpha, None);
        let mut s_cold = Solver::new(KernelEval::new(train, kernel), SmoParams::with_c(2.0));
        let rc = s_cold.solve();
        assert!(rw.converged && rc.converged);
        assert!(
            rw.iterations < rc.iterations,
            "TOP warm {} vs cold {}",
            rw.iterations,
            rc.iterations
        );
    }

    #[test]
    fn removed_nonsupport_is_noop() {
        // If the left-out instance has α = 0, the seed equals the original
        // α restricted to the survivors.
        let n = 60;
        let full = crate::data::synth::generate("heart", Some(n), 9);
        let kernel = Kernel::rbf(0.2);
        let mut solver =
            Solver::new(KernelEval::new(full.clone(), kernel), SmoParams::with_c(2.0));
        let r = solver.solve();
        let Some(t) = (0..n).find(|&i| r.alpha[i] == 0.0) else {
            return; // no non-SV in this draw; nothing to test
        };
        let f = r.f_indicators(&full.y);
        let prev_train: Vec<usize> = (0..n).collect();
        let next_train: Vec<usize> = (0..n).filter(|&i| i != t).collect();
        let ctx = SeedContext {
            full: &full,
            kernel,
            c: 2.0,
            prev_train: &prev_train,
            prev_alpha: &r.alpha,
            prev_f: &f,
            prev_b: r.b,
            removed: &[t],
            added: &[],
            next_train: &next_train,
            rng_seed: 1,
        };
        let mut cache =
            KernelCache::with_byte_budget(KernelEval::new(full.clone(), kernel), 16 << 20);
        let seed = Top.seed(&ctx, &mut cache);
        for (np, &gi) in next_train.iter().enumerate() {
            assert_eq!(seed.alpha[np], r.alpha[gi]);
        }
    }
}
