//! Multiple Instance Replacement (paper §3.2, Algorithm 2).
//!
//! Keeps α_𝓢 unchanged and estimates α'_𝒯 in one shot by solving the
//! least-squares system of paper Eq. (17)/(18):
//!
//! ```text
//!   [ Q_{X,T} ]            [ y ⊙ Δf + Q_{X,R}·α_R ]
//!   [  y_T^T  ] · α'_T  ≈  [     y_R^T·α_R        ]
//! ```
//!
//! with Δfᵢ = b − fᵢ for bounded instances (pushing each indicator exactly
//! to the bias) and Δfᵢ = 0 for the margin set. The solution is clipped to
//! the box and re-balanced to satisfy Σ_t y_t·α'_t = Σ_r y_r·α_r (Eq. 16).

use super::{balance_to_target, pos_of, SeedContext, SeedResult, Seeder};
use crate::kernel::KernelCache;
use crate::linalg::{lstsq, Mat};

/// Multiple Instance Replacement.
#[derive(Debug, Default, Clone, Copy)]
pub struct Mir;

impl Seeder for Mir {
    fn name(&self) -> &'static str {
        "mir"
    }

    fn seed(&self, ctx: &SeedContext, cache: &mut KernelCache) -> SeedResult {
        let n = ctx.prev_train.len();
        let nt = ctx.added.len();
        let next = ctx.next_train;
        let c = ctx.c;
        let y = &ctx.full.y;

        // Base: copy shared α (α'_s = α_s).
        let mut alpha = vec![0.0f64; next.len()];
        for (p, &gi) in ctx.prev_train.iter().enumerate() {
            if ctx.prev_alpha[p] > 0.0 {
                if let Some(np) = pos_of(next, gi) {
                    alpha[np] = ctx.prev_alpha[p];
                }
            }
        }

        // Target for the Σyα balance (Eq. 16): what 𝓡 carried away.
        let target: f64 = ctx
            .removed
            .iter()
            .map(|&gr| {
                let p = pos_of(ctx.prev_train, gr).expect("R ⊄ prev_train");
                y[gr] * ctx.prev_alpha[p]
            })
            .sum();

        if nt == 0 {
            // Degenerate (LOO-style) transition: nothing to estimate; just
            // rebalance the copied α to absorb the removed mass.
            let mut a = alpha.clone();
            let ny: Vec<f64> = next.iter().map(|&gi| y[gi]).collect();
            let fell_back = !balance_to_target(&mut a, &ny, c, 0.0);
            return SeedResult {
                alpha: if fell_back { vec![0.0; next.len()] } else { a },
                fell_back,
            };
        }

        // ---- Build the (n+1) × |T| system --------------------------------
        // rhs_i = yᵢ·Δfᵢ + (Q_{X,R}·α_R)ᵢ   for i ∈ X;  rhs_n = y_R^T·α_R
        // Δfᵢ = b − fᵢ for i ∈ I_u ∪ I_l, 0 for i ∈ I_m.
        let mut rhs = vec![0.0f64; n + 1];
        for (i, &gi) in ctx.prev_train.iter().enumerate() {
            let a = ctx.prev_alpha[i];
            let free = a > 0.0 && a < c;
            let df = if free { 0.0 } else { ctx.prev_b - ctx.prev_f[i] };
            rhs[i] = y[gi] * df;
        }
        // += Q_{X,R}·α_R: one cached global kernel row per support vector
        // of 𝓡 (Q_{i,r} = yᵢ·y_r·K(i,r)).
        for &gr in ctx.removed {
            let p = pos_of(ctx.prev_train, gr).expect("R ⊄ prev_train");
            let ar = ctx.prev_alpha[p];
            if ar <= 0.0 {
                continue;
            }
            let coef = ar * y[gr];
            let row = cache.row(gr);
            for (i, &gi) in ctx.prev_train.iter().enumerate() {
                rhs[i] += y[gi] * coef * row.get(gi);
            }
        }
        rhs[n] = target;

        // A = [Q_{X,T}; y_T^T], column t = y_X ⊙ y_t·K(X, x_t).
        let mut a_mat = Mat::zeros(n + 1, nt);
        for (t, &gt) in ctx.added.iter().enumerate() {
            let yt = y[gt];
            let row = cache.row(gt);
            for (i, &gi) in ctx.prev_train.iter().enumerate() {
                a_mat[(i, t)] = y[gi] * yt * row.get(gi);
            }
            a_mat[(n, t)] = yt;
        }

        // Least squares; Householder QR first, pseudo-inverse of the
        // normal equations when rank-deficient (the paper's prescription).
        let mut at = match lstsq(&a_mat, &rhs) {
            Ok(x) => x,
            Err(_) => {
                let ata = a_mat.t().matmul(&a_mat);
                let atb = a_mat.t_matvec(&rhs);
                ata.pinv().matvec(&atb)
            }
        };

        // ---- AdjustAlpha: clip + rebalance to Eq. 16 ----------------------
        let t_y: Vec<f64> = ctx.added.iter().map(|&gt| y[gt]).collect();
        let balanced = balance_to_target(&mut at, &t_y, c, target);
        if !balanced {
            return SeedResult {
                alpha: vec![0.0; next.len()],
                fell_back: true,
            };
        }
        for (t, &gt) in ctx.added.iter().enumerate() {
            let np = pos_of(next, gt).expect("T ⊄ next_train");
            alpha[np] = at[t];
        }
        SeedResult {
            alpha,
            fell_back: false,
        }
    }

    fn seed_active_set(
        &self,
        ctx: &SeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        // MIR keeps α_𝓢 fixed by construction, so a shared bounded
        // instance is the safest possible carry: its indicator is exactly
        // where round h left it, up to the estimated 𝒯 contribution.
        Some(super::carry_bounded_positions(
            ctx.prev_train,
            prev_partition,
            ctx.next_train,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::test_support::solved_round;
    use crate::seeding::{check_feasible, ColdStart, Seeder};

    #[test]
    fn seed_is_feasible() {
        let sr = solved_round("heart", 120, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let r = Mir.seed(&sr.ctx(), &mut cache);
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        check_feasible(&r.alpha, &y, sr.c).unwrap();
    }

    #[test]
    fn shared_alpha_unchanged() {
        let sr = solved_round("heart", 120, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let r = Mir.seed(&sr.ctx(), &mut cache);
        if r.fell_back {
            return;
        }
        for (p, &gi) in sr.prev_train.iter().enumerate() {
            if sr.removed.contains(&gi) {
                continue;
            }
            let np = sr.next_train.binary_search(&gi).unwrap();
            assert!(
                (r.alpha[np] - sr.prev_alpha[p]).abs() < 1e-12,
                "α_S changed at {gi}"
            );
        }
    }

    #[test]
    fn reduces_iterations_vs_cold() {
        let sr = solved_round("heart", 150, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let seeded = Mir.seed(&sr.ctx(), &mut cache);
        let cold = ColdStart.seed(&sr.ctx(), &mut cache);
        let (it_seeded, obj_s, _) = sr.solve_next(seeded.alpha);
        let (it_cold, obj_c, _) = sr.solve_next(cold.alpha);
        assert!(
            it_seeded < it_cold,
            "MIR did not reduce iterations: {it_seeded} vs cold {it_cold}"
        );
        assert!((obj_s - obj_c).abs() < 1e-3 * obj_c.abs().max(1.0));
    }

    #[test]
    fn works_on_sparse_data() {
        let sr = solved_round("adult", 200, 5, 100.0, 0.5);
        let mut cache = sr.cache();
        let r = Mir.seed(&sr.ctx(), &mut cache);
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        check_feasible(&r.alpha, &y, sr.c).unwrap();
    }

    #[test]
    fn all_bounded_regime() {
        // madelon: all α at the bound; MIR must still emit a feasible seed
        let sr = solved_round("madelon", 100, 5, 1.0, std::f64::consts::FRAC_1_SQRT_2);
        let mut cache = sr.cache();
        let r = Mir.seed(&sr.ctx(), &mut cache);
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        check_feasible(&r.alpha, &y, sr.c).unwrap();
    }
}
