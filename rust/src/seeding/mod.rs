//! Alpha-seeding algorithms — the paper's contribution (§3) plus the
//! leave-one-out baselines from the supplementary material.
//!
//! Every algorithm consumes the solved round-h SVM plus the 𝓡/𝒯/𝓢 fold
//! transition and emits an initial α for round h+1 that is **feasible**
//! (0 ≤ αᵢ ≤ C and Σyᵢαᵢ = 0), which `smo::Solver::solve_from` then
//! polishes to optimality:
//!
//! | Seeder | Paper | Strategy |
//! |--------|-------|----------|
//! | [`ColdStart`] | baseline | α = 0 (LibSVM semantics) |
//! | [`Ato`] | §3.1 | ramp α_𝒯 up / α_𝓡 down, compensating on the margin set |
//! | [`Mir`] | §3.2 | one least-squares solve for α_𝒯 (Eq. 18) |
//! | [`Sir`] | §3.3 | per-instance similarity transplant |
//! | [`Avg`] | suppl. | LOO: spread the removed α uniformly over free SVs |
//! | [`Top`] | suppl. | LOO: give the removed α to the most similar SVs |
//!
//! The same fold-overlap argument applies to the other LibSVM
//! formulations, whose duals share the box + single-equality structure:
//! [`svr`] carries ATO/MIR/SIR over to the ε-SVR pair variables
//! δ = α − α* (box \[−C, C\], Σδ = 0) and [`oneclass`] to the one-class
//! constraint Σα = ν·n. The reuse argument also extends beyond the fold
//! axis: [`gamma`] projects a solved cell's α across a γ step in grid
//! search through the same clip-and-rebalance machinery, so adjacent-γ
//! cells seed warm instead of cold. docs/SEEDING.md maps every rule to
//! its paper section and derives the transfers.

mod ato;
mod avg;
mod balance;
mod cold;
pub mod gamma;
mod mir;
pub mod oneclass;
mod sir;
pub mod svr;
mod top;

pub use ato::Ato;
pub use avg::Avg;
pub use balance::balance_to_target;
pub use cold::ColdStart;
pub use mir::Mir;
pub use sir::Sir;
pub use top::Top;

use crate::data::Dataset;
use crate::kernel::{Kernel, KernelCache};

/// Everything a seeder may use from round h to initialise round h+1.
/// All index slices hold **global** indices into `full` and are sorted
/// ascending except `removed`/`added` (fold order).
pub struct SeedContext<'a> {
    /// The complete dataset (all k folds).
    pub full: &'a Dataset,
    /// The kernel both rounds train with.
    pub kernel: Kernel,
    /// The box constraint C both rounds train with.
    pub c: f64,
    /// Round h's training instances.
    pub prev_train: &'a [usize],
    /// Round h's optimal α, aligned with `prev_train`.
    pub prev_alpha: &'a [f64],
    /// Round h's optimality indicators fᵢ = yᵢGᵢ, aligned with `prev_train`.
    pub prev_f: &'a [f64],
    /// Round h's bias b.
    pub prev_b: f64,
    /// 𝓡: leaving the training set (fold h+1).
    pub removed: &'a [usize],
    /// 𝒯: entering the training set (fold h, round h's test set).
    pub added: &'a [usize],
    /// Round h+1's training instances (= prev_train ∖ 𝓡 ∪ 𝒯, sorted).
    pub next_train: &'a [usize],
    /// Deterministic seed for any stochastic tie-breaking (SIR fallback).
    pub rng_seed: u64,
}

/// Outcome of a seeding step.
#[derive(Debug, Clone)]
pub struct SeedResult {
    /// Initial α aligned with `ctx.next_train`.
    pub alpha: Vec<f64>,
    /// True if the algorithm had to fall back to the cold start (e.g. the
    /// Σyα balance was unreachable within the box).
    pub fell_back: bool,
}

/// An alpha-seeding strategy: given round h's solved SVM and the fold
/// transition (𝓡 leaving, 𝒯 entering, 𝓢 shared), produce a feasible
/// initial α for round h+1 so the SMO solver starts near the optimum
/// instead of at zero.
///
/// Contract:
///
/// - **Feasibility** — the returned α satisfies 0 ≤ αᵢ ≤ C and
///   Σᵢ yᵢ·αᵢ = 0 (checked by [`check_feasible`] in debug builds); an
///   infeasible estimate must be repaired (see [`balance_to_target`]) or
///   abandoned via [`SeedResult::fell_back`].
/// - **Determinism** — same `SeedContext` (including `rng_seed`) ⇒ same
///   seed, regardless of thread count or scheduling; any tie-breaking
///   randomness must come from `ctx.rng_seed` only.
/// - **No effect on the solution** — seeding moves the solver's *start*,
///   never its fixed point: the paper's headline guarantee is that
///   seeded CV reaches the same accuracy as cold-started CV.
///
/// Implementations are stateless value types, `Send + Sync` so the
/// coordinator can ship jobs holding a seeder to worker threads.
pub trait Seeder: Send + Sync {
    /// Short name for tables ("sir", "mir", ...).
    fn name(&self) -> &'static str;

    /// Produce a feasible initial α for round h+1. `cache` is an LRU of
    /// kernel rows over the **full** dataset (global indices), shared
    /// across the whole cross-validation run.
    fn seed(&self, ctx: &SeedContext, cache: &mut KernelCache) -> SeedResult;

    /// Optional **cross-fold active-set carry-over**: map round h's
    /// terminal bound partition (`prev_partition`, aligned with
    /// `ctx.prev_train` — see [`SmoResult::partition`](crate::smo::SmoResult))
    /// onto round h+1's layout and return the positions the solver should
    /// treat as *initially shrunk*. The default (`None`) starts from the
    /// full active set; SIR/MIR/ATO override it with
    /// [`carry_bounded_positions`] — the same 𝓢-preserving index transfer
    /// they use for the α values, resting on the same paper argument
    /// (round h's SVM predicts round h+1's support vectors, hence also
    /// its *non*-support vectors). The guess is only a hint: the solver
    /// re-validates every proposed position against the current gradient
    /// ([`ActiveSet::seeded`](crate::smo::ActiveSet::seeded)), so a wrong
    /// carry can never change the converged model.
    fn seed_active_set(
        &self,
        ctx: &SeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        let _ = (ctx, prev_partition);
        None
    }
}

/// The shared carry-over index transfer: next-round positions of the
/// instances that stayed in the training set (𝓢) and sat at a box bound
/// (`Lower`/`Upper`) in round h's solution. Entering 𝒯 instances are
/// never proposed (their status is unknown before solving). Positions
/// come back ascending.
pub fn carry_bounded_positions(
    prev_train: &[usize],
    prev_partition: &[crate::smo::VarBound],
    next_train: &[usize],
) -> Vec<usize> {
    debug_assert_eq!(prev_train.len(), prev_partition.len());
    let mut out = Vec::new();
    for (p, &gi) in prev_train.iter().enumerate() {
        if prev_partition[p] != crate::smo::VarBound::Free {
            if let Some(np) = pos_of(next_train, gi) {
                out.push(np);
            }
        }
    }
    out
}

/// Positions of the bounded (`Lower`/`Upper`) variables of a partition —
/// the **identity-map** carry used by the warm-C chains, where the
/// training set (and hence the variable layout) is unchanged between
/// consecutive solves, so no fold-transition transfer is needed.
pub fn bounded_positions(partition: &[crate::smo::VarBound]) -> Vec<usize> {
    partition
        .iter()
        .enumerate()
        .filter(|(_, &vb)| vb != crate::smo::VarBound::Free)
        .map(|(p, _)| p)
        .collect()
}

/// Look up a seeder by canonical name.
pub fn seeder_by_name(name: &str) -> Option<Box<dyn Seeder>> {
    match name {
        "cold" | "libsvm" => Some(Box::new(ColdStart)),
        "ato" => Some(Box::new(Ato::default())),
        "mir" => Some(Box::new(Mir)),
        "sir" => Some(Box::new(Sir)),
        "avg" => Some(Box::new(Avg)),
        "top" => Some(Box::new(Top)),
        _ => None,
    }
}

/// Names of the k-fold seeders, baseline first (Table 1 ordering).
pub const ALL_SEEDERS: &[&str] = &["cold", "ato", "mir", "sir"];
/// Names of the LOO comparison set (Figure 2 ordering).
pub const LOO_SEEDERS: &[&str] = &["cold", "avg", "top", "ato", "mir", "sir"];

/// Position of global index `gi` in a sorted index slice.
#[inline]
pub(crate) fn pos_of(sorted: &[usize], gi: usize) -> Option<usize> {
    sorted.binary_search(&gi).ok()
}

/// Greedy similarity transplant shared by the ε-SVR and one-class
/// chains: visit the removed instances in descending |weight| order and
/// hand each non-zero weight to the most similar (maximal cached kernel
/// value) unused entering instance — one kernel row per donor.
/// `place(next_pos, weight)` writes the received weight into the
/// caller's seed vector; donors left over once 𝒯 is exhausted are
/// skipped (the caller's balance pass absorbs the residual). The binary
/// SIR keeps its own loop: its candidate filter (same label) and
/// deterministic random fallback have no analogue here.
pub(crate) fn transplant_by_similarity(
    removed: &[usize],
    weights: &[f64],
    added: &[usize],
    next_train: &[usize],
    cache: &mut KernelCache,
    mut place: impl FnMut(usize, f64),
) {
    debug_assert_eq!(removed.len(), weights.len());
    let mut order: Vec<usize> = (0..removed.len()).collect();
    order.sort_by(|&a, &b| weights[b].abs().partial_cmp(&weights[a].abs()).unwrap());
    let mut used = vec![false; added.len()];
    for &ri in &order {
        let w = weights[ri];
        if w == 0.0 {
            continue;
        }
        let row = cache.row(removed[ri]);
        let mut best: Option<(usize, f64)> = None;
        for (ti, &gt) in added.iter().enumerate() {
            if used[ti] {
                continue;
            }
            let k = row.get(gt);
            if best.map(|(_, bk)| k > bk).unwrap_or(true) {
                best = Some((ti, k));
            }
        }
        if let Some((ti, _)) = best {
            used[ti] = true;
            let np = pos_of(next_train, added[ti]).expect("T ⊄ next_train");
            place(np, w);
        }
    }
}

/// Validate a seed result against the feasibility contract; used by tests
/// and debug assertions in the CV driver.
pub fn check_feasible(alpha: &[f64], y: &[f64], c: f64) -> Result<(), String> {
    for (i, &a) in alpha.iter().enumerate() {
        if !(-1e-9..=c + 1e-9).contains(&a) {
            return Err(format!("alpha[{i}] = {a} outside [0, {c}]"));
        }
    }
    let s: f64 = alpha.iter().zip(y).map(|(a, yy)| a * yy).sum();
    if s.abs() > 1e-6 * c * (alpha.len() as f64).max(1.0) {
        return Err(format!("sum y·alpha = {s} != 0"));
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::FoldPlan;
    use crate::kernel::KernelEval;
    use crate::smo::{SmoParams, Solver};

    /// Solve round h of a CV plan and package a SeedContext's owned parts.
    pub struct SolvedRound {
        pub full: Dataset,
        pub kernel: Kernel,
        pub c: f64,
        pub prev_train: Vec<usize>,
        pub prev_alpha: Vec<f64>,
        pub prev_f: Vec<f64>,
        pub prev_b: f64,
        pub removed: Vec<usize>,
        pub added: Vec<usize>,
        pub next_train: Vec<usize>,
    }

    impl SolvedRound {
        pub fn ctx(&self) -> SeedContext<'_> {
            SeedContext {
                full: &self.full,
                kernel: self.kernel,
                c: self.c,
                prev_train: &self.prev_train,
                prev_alpha: &self.prev_alpha,
                prev_f: &self.prev_f,
                prev_b: self.prev_b,
                removed: &self.removed,
                added: &self.added,
                next_train: &self.next_train,
                rng_seed: 7,
            }
        }

        pub fn cache(&self) -> KernelCache {
            KernelCache::with_byte_budget(
                KernelEval::new(self.full.clone(), self.kernel),
                64 << 20,
            )
        }

        /// Solve round h+1 from a given seed; returns (iterations, obj, b).
        pub fn solve_next(&self, alpha0: Vec<f64>) -> (u64, f64, f64) {
            let train = self.full.select(&self.next_train);
            let mut solver = Solver::new(
                KernelEval::new(train, self.kernel),
                SmoParams::with_c(self.c),
            );
            let r = solver.solve_from(alpha0, None);
            assert!(r.converged);
            (r.iterations, r.objective, r.b)
        }
    }

    /// Train round h=0 of a k-fold plan on a synthetic dataset.
    pub fn solved_round(dataset: &str, n: usize, k: usize, c: f64, gamma: f64) -> SolvedRound {
        let full = crate::data::synth::generate(dataset, Some(n), 42);
        let kernel = Kernel::rbf(gamma);
        let plan = FoldPlan::stratified(&full, k, 11);
        let h = 0;
        let prev_train = plan.train_indices(h);
        let train = full.select(&prev_train);
        let mut solver = Solver::new(KernelEval::new(train.clone(), kernel), SmoParams::with_c(c));
        let r = solver.solve();
        assert!(r.converged, "round-0 solve did not converge");
        let prev_f = r.f_indicators(&train.y);
        let t = plan.transition(h);
        SolvedRound {
            full,
            kernel,
            c,
            prev_train,
            prev_alpha: r.alpha,
            prev_f,
            prev_b: r.b,
            removed: t.removed,
            added: t.added,
            next_train: plan.train_indices(h + 1),
        }
    }
}
