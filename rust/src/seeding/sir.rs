//! Single Instance Replacement (paper §3.3, Algorithm 3) — the paper's
//! best-performing seeder.
//!
//! For each removed instance x_p with α_p > 0, find the unused added
//! instance x_q that is *most similar* (same label, maximal kernel value
//! K(x_p, x_q)) and transplant α_p onto it. The change to every optimality
//! indicator is then Δfᵢ = α_p(y_q·K(xᵢ,x_q) − y_p·K(xᵢ,x_p)) ≈ 0 (Eq. 21).
//! When no same-label instance remains, a deterministic pseudo-random one
//! is used and the resulting Σyα imbalance is repaired by *AdjustAlpha*.

use super::{balance_to_target, pos_of, SeedContext, SeedResult, Seeder};
use crate::kernel::KernelCache;
use crate::util::rng::Pcg32;

/// Single Instance Replacement.
#[derive(Debug, Default, Clone, Copy)]
pub struct Sir;

impl Seeder for Sir {
    fn name(&self) -> &'static str {
        "sir"
    }

    fn seed(&self, ctx: &SeedContext, cache: &mut KernelCache) -> SeedResult {
        let next = ctx.next_train;
        let mut alpha = vec![0.0f64; next.len()];

        // Copy the shared instances' α unchanged (α'_s = α_s).
        for (p, &gi) in ctx.prev_train.iter().enumerate() {
            if ctx.prev_alpha[p] > 0.0 {
                if let Some(np) = pos_of(next, gi) {
                    alpha[np] = ctx.prev_alpha[p];
                }
            }
        }

        // Transplant each removed α_p onto the most similar unused 𝒯
        // instance with the same label.
        let mut used = vec![false; ctx.added.len()];
        let mut rng = Pcg32::new(ctx.rng_seed, 0x51B);
        let mut any_random = false;

        // Process 𝓡 in descending α so large weights get first pick of the
        // similarity pool (deterministic; the paper leaves order open).
        let mut r_order: Vec<usize> = (0..ctx.removed.len()).collect();
        let r_alpha: Vec<f64> = ctx
            .removed
            .iter()
            .map(|&gr| {
                let p = pos_of(ctx.prev_train, gr).expect("R ⊄ prev_train");
                ctx.prev_alpha[p]
            })
            .collect();
        r_order.sort_by(|&a, &b| r_alpha[b].partial_cmp(&r_alpha[a]).unwrap());

        for &ri in &r_order {
            let ap = r_alpha[ri];
            if ap <= 0.0 {
                continue; // α_p = 0 ⇒ Δf ≡ 0, nothing to transplant
            }
            let gp = ctx.removed[ri];
            let yp = ctx.full.y[gp];
            // Most similar same-label unused t: maximal K(x_p, x_t).
            // One cached kernel row over the full dataset serves all of 𝒯.
            let row_p = cache.row(gp);
            let mut best: Option<(usize, f64)> = None;
            for (ti, &gt) in ctx.added.iter().enumerate() {
                if used[ti] || ctx.full.y[gt] != yp {
                    continue;
                }
                let k = row_p.get(gt);
                if best.map(|(_, bk)| k > bk).unwrap_or(true) {
                    best = Some((ti, k));
                }
            }
            let ti = match best {
                Some((ti, _)) => ti,
                None => {
                    // no same-label candidate left: random unused fallback
                    let free: Vec<usize> =
                        (0..ctx.added.len()).filter(|&t| !used[t]).collect();
                    if free.is_empty() {
                        // |𝒯| < number of SVs in 𝓡 — leave the residual to
                        // the balance step below.
                        any_random = true;
                        continue;
                    }
                    any_random = true;
                    free[rng.gen_range(free.len())]
                }
            };
            used[ti] = true;
            let gq = ctx.added[ti];
            let nq = pos_of(next, gq).expect("T ⊄ next_train");
            alpha[nq] = ap;
        }

        // Repair Σyα if any random (label-mismatched) replacement happened
        // or residual α could not be placed. Target: Σ_{t∈𝒯} y_t·α'_t must
        // equal Σ_{r∈𝓡} y_r·α_r (Eq. 16).
        let target: f64 = ctx
            .removed
            .iter()
            .zip(&r_alpha)
            .map(|(&gr, &a)| ctx.full.y[gr] * a)
            .sum();
        let t_positions: Vec<usize> = ctx
            .added
            .iter()
            .map(|&gt| pos_of(next, gt).expect("T ⊄ next_train"))
            .collect();
        let mut t_alpha: Vec<f64> = t_positions.iter().map(|&np| alpha[np]).collect();
        let t_y: Vec<f64> = ctx.added.iter().map(|&gt| ctx.full.y[gt]).collect();
        let current: f64 = t_alpha.iter().zip(&t_y).map(|(a, y)| a * y).sum();

        let mut fell_back = false;
        if (current - target).abs() > 1e-9 || any_random {
            if balance_to_target(&mut t_alpha, &t_y, ctx.c, target) {
                for (&np, &a) in t_positions.iter().zip(&t_alpha) {
                    alpha[np] = a;
                }
            } else {
                // Unreachable within the box: cold-start fallback.
                alpha.iter_mut().for_each(|a| *a = 0.0);
                fell_back = true;
            }
        }

        SeedResult { alpha, fell_back }
    }

    fn seed_active_set(
        &self,
        ctx: &SeedContext,
        prev_partition: &[crate::smo::VarBound],
    ) -> Option<Vec<usize>> {
        // Same 𝓢-preserving transfer as the α copy above: shared bounded
        // instances are proposed as initially shrunk (Eq. 21's Δf ≈ 0
        // argument — the transplant barely moves their indicators).
        Some(super::carry_bounded_positions(
            ctx.prev_train,
            prev_partition,
            ctx.next_train,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::test_support::solved_round;
    use crate::seeding::{check_feasible, ColdStart, Seeder};

    #[test]
    fn seed_is_feasible() {
        let sr = solved_round("heart", 120, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let r = Sir.seed(&sr.ctx(), &mut cache);
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        check_feasible(&r.alpha, &y, sr.c).unwrap();
    }

    #[test]
    fn shared_alphas_copied() {
        let sr = solved_round("heart", 120, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let r = Sir.seed(&sr.ctx(), &mut cache);
        if r.fell_back {
            return; // nothing to check on fallback
        }
        // every shared instance keeps its α
        for (p, &gi) in sr.prev_train.iter().enumerate() {
            if sr.removed.contains(&gi) {
                continue;
            }
            let np = sr.next_train.binary_search(&gi).unwrap();
            assert!(
                (r.alpha[np] - sr.prev_alpha[p]).abs() < 1e-12,
                "shared α changed at {gi}"
            );
        }
    }

    #[test]
    fn reduces_iterations_vs_cold() {
        let sr = solved_round("heart", 150, 5, 2.0, 0.2);
        let mut cache = sr.cache();
        let seeded = Sir.seed(&sr.ctx(), &mut cache);
        let cold = ColdStart.seed(&sr.ctx(), &mut cache);
        let (it_seeded, obj_s, _) = sr.solve_next(seeded.alpha);
        let (it_cold, obj_c, _) = sr.solve_next(cold.alpha);
        assert!(
            it_seeded < it_cold,
            "SIR did not reduce iterations: {it_seeded} vs cold {it_cold}"
        );
        // identical optimum
        assert!(
            (obj_s - obj_c).abs() < 1e-3 * obj_c.abs().max(1.0),
            "objectives differ: {obj_s} vs {obj_c}"
        );
    }

    #[test]
    fn transplant_prefers_same_label_similar() {
        // On the sparse adult analogue the label-match rule should hold for
        // every transplanted weight (enough candidates of each class).
        let sr = solved_round("adult", 200, 5, 100.0, 0.5);
        let mut cache = sr.cache();
        let r = Sir.seed(&sr.ctx(), &mut cache);
        if r.fell_back {
            return;
        }
        let y: Vec<f64> = sr.next_train.iter().map(|&i| sr.full.y[i]).collect();
        check_feasible(&r.alpha, &y, sr.c).unwrap();
        // 𝒯 got non-trivial mass whenever 𝓡 carried support vectors
        let removed_mass: f64 = sr
            .removed
            .iter()
            .map(|&gr| {
                let p = sr.prev_train.binary_search(&gr).unwrap();
                sr.prev_alpha[p]
            })
            .sum();
        if removed_mass > 0.0 {
            let t_mass: f64 = sr
                .added
                .iter()
                .map(|&gt| r.alpha[sr.next_train.binary_search(&gt).unwrap()])
                .sum();
            assert!(t_mass > 0.0, "no mass transplanted");
        }
    }
}
