//! `alphaseed` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   cv          one k-fold cross-validation run
//!   loo         one leave-one-out run
//!   train       train a single SVM and report the model
//!   grid        (C, γ) grid search with seeded CV
//!   datagen     write a synthetic analogue as a LibSVM file
//!   experiment  regenerate the paper's tables/figure (table1|table2|table3|fig2|all)
//!   probe       measure PJRT artifact dispatch overhead vs native
//!   serve       batched, hot-swappable TCP/JSON-lines prediction service
//!   worker      grid-worker process for sharded multi-process grid search
//!   benchgate   CI bench-regression gate over committed baselines

use alphaseed::config::{RunConfig, RunProfile};
use alphaseed::coordinator::{
    experiments, BudgetPolicy, ModelRegistry, PredictServer, ServeModel,
};
use alphaseed::cv::CvReport;
use alphaseed::data::{read_libsvm, synth, write_libsvm};
use alphaseed::kernel::{Kernel, KernelEval};
use alphaseed::metrics::Table;
use alphaseed::multiclass::MultiDataset;
use alphaseed::runtime::{BackendChoice, ComputeBackend, NativeBackend, XlaBackend};
use alphaseed::smo::problem::solver_for;
use alphaseed::smo::{
    Model, OneClassModel, OneClassProblem, QpProblem, SmoParams, Solver, SvrModel, SvrProblem,
};
use alphaseed::util::bench::{
    check_bench_regression, check_grid_regression, check_kernel_regression,
    check_serve_regression, render_gate_report, render_grid_gate_report,
    render_kernel_gate_report, render_serve_gate_report, GateTolerance, ServeGateTolerance,
};
use alphaseed::util::cli::{run_profile, Args, Task};
use alphaseed::util::json::Json;
use alphaseed::util::timing::fmt_secs;
use anyhow::{bail, Context, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("cv") => cmd_cv(args),
        Some("loo") => cmd_loo(args),
        Some("train") => cmd_train(args),
        Some("grid") => cmd_grid(args),
        Some("datagen") => cmd_datagen(args),
        Some("experiment") => cmd_experiment(args),
        Some("probe") => cmd_probe(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("worker") => cmd_worker(args),
        Some("ovo") => cmd_ovo(args),
        Some("benchgate") => cmd_benchgate(args),
        Some(other) => bail!("unknown subcommand '{other}' (run with no args for help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "alphaseed — SVM k-fold cross-validation with alpha seeding (AAAI'17 reproduction)\n\
         \n\
         USAGE: alphaseed <cv|loo|train|grid|datagen|experiment|probe|ovo|serve|worker|benchgate> [options]\n\
         \n\
         common options:\n\
           --task <t>          csvc|svr|oneclass|multiclass    (default csvc)\n\
           --dataset <name>    csvc: adult|heart|madelon|mnist|webdata\n\
                               svr:  sinc|friedman1 (synthetic regression)\n\
                               multiclass: blobs|rings (synthetic)\n\
           --data <file>       LibSVM-format file instead of a synthetic analogue\n\
                               (multiclass: integer class labels)\n\
           --n <int>           override analogue cardinality\n\
           --c <f> --gamma <f> hyper-parameters (defaults: paper Table 2)\n\
           --seeder <name>     cold|ato|mir|sir|avg|top        (default sir)\n\
           --k <int>           folds                           (default 10)\n\
           --backend <b>       native|xla                      (default native)\n\
           --cache-f32         store kernel-cache rows as f32 (2x row capacity;\n\
                               accumulation stays f64 — see docs/ARCHITECTURE.md §3.7)\n\
           --seed <int>        RNG seed                        (default 42)\n\
           --solver-eps <f>    SMO KKT tolerance               (default 1e-3)\n\
           --no-shrinking      disable the shrinking heuristic\n\
           --no-carry          disable cross-fold active-set carry-over\n\
           --cache-mb <int>    solver kernel-cache budget       (default 256)\n\
           --seed-cache-mb <int> seeding-cache budget (default 128; grids 64)\n\
           --threads <int>     worker threads, 0 = auto        (default 0)\n\
           --no-share-rows     private per-cell kernel caches (grids/ovo only)\n\
         svr / oneclass options:\n\
           --epsilon <f>       SVR tube half-width             (default per dataset)\n\
           --nu <f>            one-class outlier-fraction bound (default 0.15)\n\
           --outlier-frac <f>  contamination of the synthetic set (default 0.1)\n\
         multiclass options (cv/ovo/grid --task multiclass):\n\
           --classes <int>     synthetic class count              (default 3)\n\
           --sep/--noise <f>   blobs separation / rings noise\n\
         grid options:\n\
           --warm-c            chain ascending C per gamma (Chu et al. reuse)\n\
           --seed-gamma        seed round 0 from the adjacent-γ cell's alphas\n\
           --budget-policy <p> uniform|halving                 (default uniform)\n\
           --eta <int>         halving keep fraction 1/eta     (default 3)\n\
           --min-rounds <int>  halving round-0 folds per cell  (default 1)\n\
           --eps-grid <list>   SVR tube-width axis (with --task svr)\n\
           --workers <list>    host:port grid-worker addresses; ships per-γ\n\
                               node groups to worker processes (csvc only;\n\
                               bit-identical to the single-process run —\n\
                               docs/DISTRIBUTED.md §3)\n\
           --shard-bytes <int> shard the --data file on disk and fill worker\n\
                               kernel caches from resident shards (§2)\n\
           --points-out <file> write the evaluated cells as deterministic\n\
                               JSON (wall times excluded; CI diffs sharded\n\
                               vs single-process dumps byte-for-byte)\n\
           --journal <file>    (with --workers) append completed cells as\n\
                               JSON lines; re-running with the same journal\n\
                               resumes the grid bit-identically after a\n\
                               crash (docs/DISTRIBUTED.md §4)\n\
           --lease-secs <f>    per-cell lease before a silent worker is\n\
                               declared hung and forfeits its cells (default 60)\n\
           --heartbeat-secs <f> ping interval while awaiting a worker reply\n\
                               (default 2)\n\
           --dispatch-retries <int> attempts per request on transient worker\n\
                               failures (default 3)\n\
         worker options:\n\
           --port <int>        TCP port (default 7879; 0 picks a free port)\n\
           --drain-secs <f>    shutdown drain deadline for in-flight\n\
                               connections (default 10)\n\
         serve options:\n\
           --task <t>          csvc|svr|oneclass model to train and serve\n\
           --port <int>        TCP port (default 7878; 0 picks a free port)\n\
           --probs             Platt-calibrate C-SVC probabilities (seeded CV)\n\
           --backend <b>       native|xla batched decision fills (default native;\n\
                               xla falls back to native per request if unavailable)\n\
           --drain-secs <f>    shutdown drain deadline for in-flight\n\
                               connections (default 10)\n\
         benchgate options:\n\
           --current <file>    freshly emitted BENCH_*.json\n\
           --baseline <file>   committed BENCH_*.baseline.json\n\
           --iter-tol <f>      relative iteration-ratio tolerance (default 0.05)\n\
           --init-frac-tol <f> absolute init-fraction tolerance   (default 0.15)\n\
           --speedup-tol <f>   relative serve batching-ratio slack (default 0.5)\n\
           --report <file>     also write a markdown ratio summary (CI artifact)\n\
         experiment options:\n\
           --scale <f>         scale dataset sizes (default 1.0)\n\
           --out <dir>         results directory (default results/)\n\
           --loo-rounds <int>  LOO estimation prefix for fig2 (default 40)\n\
           --ks <list>         table3 k values (default 3,10,100)"
    );
}

/// Load the dataset a command refers to (--data file or --dataset name).
fn load_dataset(args: &Args) -> Result<(alphaseed::data::Dataset, f64, f64)> {
    let seed = args.parse_or::<u64>("seed", 42)?;
    if let Some(path) = args.opt_str("data") {
        let ds = read_libsvm(&path)?;
        let c = args.parse_or("c", 1.0)?;
        let gamma = args.parse_or("gamma", 1.0 / ds.dim() as f64)?;
        Ok((ds, c, gamma))
    } else {
        let name = args.str_or("dataset", "heart");
        let spec = synth::spec(&name).with_context(|| format!("unknown dataset '{name}'"))?;
        let n = args.opt_parse::<usize>("n")?;
        let ds = synth::generate(&name, n, seed);
        let c = args.parse_or("c", spec.hyper.c)?;
        let gamma = args.parse_or("gamma", spec.hyper.gamma)?;
        Ok((ds, c, gamma))
    }
}

/// `--backend native` (default) uses the CV driver's in-process cached
/// path (`None` here); `--backend xla` routes bulk ops to the AOT
/// artifacts through PJRT.
fn make_backend(args: &Args) -> Result<Option<XlaBackend>> {
    match args.str_or("backend", "native").parse::<BackendChoice>() {
        Ok(BackendChoice::Native) => Ok(None),
        Ok(BackendChoice::Xla) => {
            let dir = XlaBackend::default_dir();
            let b = XlaBackend::load(&dir)
                .with_context(|| format!("loading artifacts from {dir:?} (make artifacts)"))?;
            Ok(Some(b))
        }
        Err(e) => bail!(e),
    }
}

/// Reject an option or flag that doesn't apply to this subcommand with a
/// targeted message (instead of the generic "unknown option" the
/// consumed-keys check would give).
fn reject_opt(args: &Args, key: &str, msg: &str) -> Result<()> {
    if args.opt_str(key).is_some() || args.flag(key) {
        bail!("--{key}: {msg}");
    }
    Ok(())
}

/// Parse `--budget-policy`, `--eta`, `--min-rounds` and `--seed-gamma`
/// for the grid subcommands, rejecting the combinations the scheduler
/// does not support with targeted messages.
fn grid_policy_args(args: &Args, warm_c: bool, multiclass: bool) -> Result<(BudgetPolicy, bool)> {
    let policy_name = args.str_or("budget-policy", "uniform");
    let eta = args.opt_parse::<usize>("eta")?;
    let min_rounds = args.opt_parse::<usize>("min-rounds")?;
    let seed_gamma = args.flag("seed-gamma");
    let policy = match policy_name.as_str() {
        "uniform" => {
            if eta.is_some() || min_rounds.is_some() {
                bail!("--eta/--min-rounds tune successive halving; add --budget-policy halving");
            }
            BudgetPolicy::Uniform
        }
        "halving" | "successive-halving" => {
            if multiclass {
                bail!(
                    "--budget-policy halving is not supported for multiclass grids: a cell's \
                     metric pools all pair chains, which cannot pause at a fold boundary"
                );
            }
            if warm_c {
                bail!(
                    "--budget-policy halving cannot compose with --warm-c: the C-chain couples \
                     cells that halving must keep or drop independently"
                );
            }
            let eta = eta.unwrap_or(3);
            if eta < 2 {
                bail!("--eta {eta}: successive halving needs eta >= 2");
            }
            BudgetPolicy::SuccessiveHalving {
                eta,
                min_rounds: min_rounds.unwrap_or(1),
            }
        }
        other => bail!("unknown --budget-policy '{other}' (uniform|halving)"),
    };
    if seed_gamma {
        if multiclass {
            bail!(
                "--seed-gamma is not supported for multiclass grids: pair chains restart cold \
                 on degenerate folds, so a cross-γ donor is not always defined"
            );
        }
        if warm_c {
            bail!(
                "--seed-gamma cannot compose with --warm-c: pick one reuse direction \
                 (cross-γ rows or ascending-C columns)"
            );
        }
    }
    Ok((policy, seed_gamma))
}

fn print_report(rep: &CvReport) {
    let mut t = Table::new(format!(
        "{} / {} (k = {}, {} rounds run)",
        rep.dataset,
        rep.seeder,
        rep.k,
        rep.rounds.len()
    ))
    .header(&["metric", "value"]);
    t.row(vec!["init time (s)".into(), fmt_secs(rep.total_init())]);
    t.row(vec!["rest time (s)".into(), fmt_secs(rep.total_rest())]);
    t.row(vec!["total (s)".into(), fmt_secs(rep.total_elapsed())]);
    t.row(vec![
        "init fraction (%)".into(),
        format!("{:.2}", rep.init_fraction() * 100.0),
    ]);
    t.row(vec!["iterations".into(), rep.total_iterations().to_string()]);
    t.row(vec![
        "accuracy (%)".into(),
        format!("{:.2}", rep.accuracy() * 100.0),
    ]);
    t.row(vec!["seed fallbacks".into(), rep.fallbacks().to_string()]);
    print!("{}", t.render());
}

fn print_svr_report(rep: &CvReport) {
    let mut t = Table::new(format!(
        "{} / svr+{} (k = {}, {} rounds run)",
        rep.dataset,
        rep.seeder,
        rep.k,
        rep.rounds.len()
    ))
    .header(&["metric", "value"]);
    t.row(vec!["init time (s)".into(), fmt_secs(rep.total_init())]);
    t.row(vec!["rest time (s)".into(), fmt_secs(rep.total_rest())]);
    t.row(vec!["total (s)".into(), fmt_secs(rep.total_elapsed())]);
    t.row(vec![
        "init fraction (%)".into(),
        format!("{:.2}", rep.init_fraction() * 100.0),
    ]);
    t.row(vec!["iterations".into(), rep.total_iterations().to_string()]);
    t.row(vec!["CV MSE".into(), format!("{:.6}", rep.mse())]);
    t.row(vec![
        "within ε-tube (%)".into(),
        format!("{:.2}", rep.accuracy() * 100.0),
    ]);
    t.row(vec!["seed fallbacks".into(), rep.fallbacks().to_string()]);
    print!("{}", t.render());
}

/// Load the regression dataset an `--task svr` command refers to.
fn load_regression_dataset(args: &Args) -> Result<(alphaseed::data::Dataset, f64, f64, f64)> {
    if args.opt_str("data").is_some() {
        bail!("--task svr reads synthetic regression sets (--dataset sinc|friedman1); LibSVM regression files are not wired yet");
    }
    let seed = args.parse_or::<u64>("seed", 42)?;
    let name = args.str_or("dataset", "sinc");
    let (hyper, default_eps) = synth::regression_hyper(&name)
        .with_context(|| format!("unknown regression dataset '{name}' (sinc|friedman1)"))?;
    let n = args.opt_parse::<usize>("n")?;
    let ds = synth::generate_regression(&name, n, seed);
    let c = args.parse_or("c", hyper.c)?;
    let gamma = args.parse_or("gamma", hyper.gamma)?;
    let epsilon = args.parse_or("epsilon", default_eps)?;
    Ok((ds, c, gamma, epsilon))
}

fn cmd_cv(args: &Args) -> Result<()> {
    match args.parse_or("task", Task::CSvc)? {
        Task::CSvc => cmd_cv_csvc(args),
        Task::Svr => cmd_cv_svr(args),
        Task::OneClass => cmd_cv_oneclass(args),
        Task::Multiclass => cmd_ovo(args),
    }
}

/// The general-solver tasks run natively only; accept the default
/// `--backend native` and reject `xla` with a targeted message (instead
/// of the generic "unknown option" the consumed-keys check would give).
fn reject_xla_backend(args: &Args, task: &str) -> Result<()> {
    match args.str_or("backend", "native").as_str() {
        "native" => Ok(()),
        other => bail!("--task {task} runs natively; --backend {other} is not supported"),
    }
}

fn cmd_cv_svr(args: &Args) -> Result<()> {
    reject_xla_backend(args, "svr")?;
    reject_opt(
        args,
        "threads",
        "the ε-SVR chain is sequential per fold; --threads applies to csvc runs and grids",
    )?;
    reject_opt(
        args,
        "no-share-rows",
        "row sharing is a grid-level concern; a single CV run builds one seeding cache",
    )?;
    let (ds, c, gamma, epsilon) = load_regression_dataset(args)?;
    let k = args.parse_or("k", 10usize)?;
    let seeder_name = args.str_or("seeder", "sir");
    let seeder = alphaseed::seeding::svr::svr_seeder_by_name(&seeder_name)
        .with_context(|| format!("unknown SVR seeder '{seeder_name}' (cold|ato|mir|sir)"))?;
    let max_rounds = args.opt_parse::<usize>("max-rounds")?;
    let profile = run_profile(args, RunProfile::default())?;
    args.reject_unknown()?;

    let rep = alphaseed::cv::run_kfold_svr(
        &ds,
        Kernel::rbf(gamma),
        c,
        epsilon,
        k,
        seeder.as_ref(),
        alphaseed::cv::CvOptions {
            profile,
            max_rounds,
            ..Default::default()
        },
    );
    print_svr_report(&rep);
    Ok(())
}

fn cmd_cv_oneclass(args: &Args) -> Result<()> {
    reject_xla_backend(args, "oneclass")?;
    if args.opt_str("data").is_some() {
        bail!("--task oneclass reads the synthetic outlier set (--n/--outlier-frac); LibSVM files are not wired yet");
    }
    if let Some(name) = args.opt_str("dataset") {
        if name != "outliers" {
            bail!("--task oneclass has one synthetic dataset ('outliers'); got --dataset {name}");
        }
    }
    if args.opt_str("c").is_some() {
        bail!("one-class SVM has no penalty C (the box is [0, 1]); use --nu to bound the outlier fraction");
    }
    if args.opt_str("epsilon").is_some() {
        bail!("--epsilon is the SVR tube width; one-class takes --nu");
    }
    let seed = args.parse_or::<u64>("seed", 42)?;
    let n = args.opt_parse::<usize>("n")?;
    let outlier_frac = args.parse_or("outlier-frac", 0.1f64)?;
    let ds = synth::generate_outliers(n, outlier_frac, seed);
    let nu = args.parse_or("nu", 0.15f64)?;
    let gamma = args.parse_or("gamma", 1.0f64)?;
    let k = args.parse_or("k", 10usize)?;
    let seeder_name = args.str_or("seeder", "sir");
    let transplant = match seeder_name.as_str() {
        "cold" | "libsvm" => false,
        "sir" | "transplant" => true,
        other => bail!("unknown one-class seeder '{other}' (cold|sir)"),
    };
    let max_rounds = args.opt_parse::<usize>("max-rounds")?;
    reject_opt(
        args,
        "threads",
        "the one-class chain is sequential per fold; --threads applies to csvc runs and grids",
    )?;
    reject_opt(
        args,
        "no-share-rows",
        "row sharing is a grid-level concern; a single CV run builds one seeding cache",
    )?;
    let profile = run_profile(args, RunProfile::default())?;
    args.reject_unknown()?;

    let rep = alphaseed::cv::run_kfold_oneclass(
        &ds,
        Kernel::rbf(gamma),
        nu,
        k,
        transplant,
        alphaseed::cv::CvOptions {
            profile,
            max_rounds,
            ..Default::default()
        },
    );
    print_report(&rep);
    Ok(())
}

fn cmd_cv_csvc(args: &Args) -> Result<()> {
    let (ds, c, gamma) = load_dataset(args)?;
    let k = args.parse_or("k", 10usize)?;
    let seeder_name = args.str_or("seeder", "sir");
    let seeder = alphaseed::seeding::seeder_by_name(&seeder_name)
        .with_context(|| format!("unknown seeder '{seeder_name}'"))?;
    let mut backend = make_backend(args)?;
    let max_rounds = args.opt_parse::<usize>("max-rounds")?;
    reject_opt(
        args,
        "no-share-rows",
        "row sharing is a grid-level concern; a single CV run builds one seeding cache",
    )?;
    reject_opt(
        args,
        "shard-bytes",
        "shard-backed row stores apply to grid runs; a single CV run keeps its dataset resident",
    )?;
    let profile = run_profile(args, RunProfile::default())?;
    args.reject_unknown()?;

    let opts = alphaseed::cv::CvOptions {
        profile,
        max_rounds,
        backend: backend
            .as_mut()
            .map(|b| b as &mut dyn ComputeBackend),
        ..Default::default()
    };
    let rep = alphaseed::cv::run_kfold(&ds, Kernel::rbf(gamma), c, k, seeder.as_ref(), opts);
    print_report(&rep);
    Ok(())
}

fn cmd_loo(args: &Args) -> Result<()> {
    let (ds, c, gamma) = load_dataset(args)?;
    let seeder_name = args.str_or("seeder", "sir");
    let seeder = alphaseed::seeding::seeder_by_name(&seeder_name)
        .with_context(|| format!("unknown seeder '{seeder_name}'"))?;
    let max_rounds = args.opt_parse::<usize>("max-rounds")?;
    reject_opt(
        args,
        "cache-f32",
        "the LOO chain reuses the CV seeding cache at its fixed dtype; f32 tiers apply to cv and grid runs",
    )?;
    reject_opt(
        args,
        "no-carry",
        "active-set carry-over is a k-fold chain optimisation; LOO rounds drop a single row each",
    )?;
    reject_opt(
        args,
        "no-share-rows",
        "row sharing is a grid-level concern; a LOO run builds one seeding cache",
    )?;
    reject_opt(
        args,
        "shard-bytes",
        "shard-backed row stores apply to grid runs; a LOO chain keeps its dataset resident",
    )?;
    let profile = run_profile(args, RunProfile::default())?;
    args.reject_unknown()?;

    let rep = alphaseed::cv::run_loo(
        &ds,
        Kernel::rbf(gamma),
        c,
        seeder.as_ref(),
        alphaseed::cv::LooOptions {
            profile,
            max_rounds,
        },
    );
    print_report(&rep);
    if rep.rounds.len() < ds.len() {
        println!(
            "estimated full-LOO total: {} s ({} of {} rounds run)",
            fmt_secs(rep.extrapolated_elapsed(ds.len())),
            rep.rounds.len(),
            ds.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (ds, c, gamma) = load_dataset(args)?;
    args.reject_unknown()?;
    let kernel = Kernel::rbf(gamma);
    let started = std::time::Instant::now();
    let mut solver = Solver::new(KernelEval::new(ds.clone(), kernel), SmoParams::with_c(c));
    let r = solver.solve();
    let model = Model::from_result(&ds, kernel, &r);
    let mut t = Table::new(format!("train {} (n={}, d={})", ds.name, ds.len(), ds.dim()))
        .header(&["metric", "value"]);
    t.row(vec!["time (s)".into(), fmt_secs(started.elapsed())]);
    t.row(vec!["iterations".into(), r.iterations.to_string()]);
    t.row(vec!["objective".into(), format!("{:.6}", r.objective)]);
    t.row(vec!["bias b".into(), format!("{:.6}", r.b)]);
    t.row(vec![
        "SVs".into(),
        format!("{} ({} bounded)", r.n_sv, r.n_bsv),
    ]);
    t.row(vec![
        "train accuracy (%)".into(),
        format!("{:.2}", model.accuracy(&ds) * 100.0),
    ]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_grid(args: &Args) -> Result<()> {
    match args.parse_or("task", Task::CSvc)? {
        Task::CSvc => cmd_grid_csvc(args),
        Task::Svr => cmd_grid_svr(args),
        Task::OneClass => bail!("grid search over one-class runs is not wired yet (use cv --task oneclass)"),
        Task::Multiclass => cmd_grid_ovo(args),
    }
}

fn cmd_grid_svr(args: &Args) -> Result<()> {
    reject_xla_backend(args, "svr")?;
    if args.flag("warm-c") {
        bail!("--warm-c chains C for the C-SVC grid; the SVR grid's ε axis changes the dual's linear term, so its cells run independently");
    }
    // checked before load_regression_dataset consumes the keys: the grid
    // sweeps its own axes, so lone point values would be silently ignored
    if args.opt_str("epsilon").is_some() {
        bail!("grid --task svr sweeps the tube width via --eps-grid; --epsilon applies to single cv runs");
    }
    if args.opt_str("c").is_some() || args.opt_str("gamma").is_some() {
        bail!("grid --task svr sweeps --c-grid/--gamma-grid; point values --c/--gamma apply to single cv runs");
    }
    let (ds, _, _, _) = load_regression_dataset(args)?;
    let cs = args.list_or("c-grid", &[1.0, 10.0, 100.0])?;
    let epss = args.list_or("eps-grid", &[0.01, 0.05, 0.2])?;
    let gammas = args.list_or("gamma-grid", &[0.1, 0.5, 1.0])?;
    let k = args.parse_or("k", 5usize)?;
    let seeder = args.str_or("seeder", "sir");
    let (policy, seed_gamma) = grid_policy_args(args, false, false)?;
    let profile = run_profile(
        args,
        alphaseed::coordinator::GridOptions::default().profile,
    )?;
    args.reject_unknown()?;

    let started = std::time::Instant::now();
    let g = alphaseed::coordinator::grid_search_svr(
        &ds,
        &cs,
        &epss,
        &gammas,
        &alphaseed::coordinator::GridOptions {
            profile,
            k,
            seeder: seeder.clone(),
            warm_c: false,
            policy,
            seed_gamma,
        },
    );
    let mut t = Table::new(format!(
        "SVR grid search on {} ({} cells, seeder {seeder}, {} s)",
        ds.name,
        g.points.len(),
        fmt_secs(started.elapsed())
    ))
    .header(&["C", "epsilon", "gamma", "CV MSE", "rounds", "iterations", "time(s)"]);
    for p in &g.points {
        t.row(vec![
            format!("{}", p.c),
            format!("{}", p.epsilon),
            format!("{}", p.gamma),
            format!("{:.6}", p.mse),
            p.rounds.to_string(),
            p.iterations.to_string(),
            fmt_secs(p.elapsed),
        ]);
    }
    print!("{}", t.render());
    let best = g.best();
    println!(
        "best: C={} epsilon={} gamma={} MSE={:.6}",
        best.c, best.epsilon, best.gamma, best.mse
    );
    Ok(())
}

/// Print the evaluated C-SVC grid and its winner (shared by the
/// single-process and `--workers` paths, whose cells are bit-identical).
fn print_csvc_grid(g: &alphaseed::coordinator::GridResult, title: String) {
    let mut t = Table::new(title)
        .header(&["C", "gamma", "accuracy(%)", "rounds", "iterations", "time(s)"]);
    for p in &g.points {
        t.row(vec![
            format!("{}", p.c),
            format!("{}", p.gamma),
            format!("{:.2}", p.accuracy * 100.0),
            p.rounds.to_string(),
            p.iterations.to_string(),
            fmt_secs(p.elapsed),
        ]);
    }
    print!("{}", t.render());
    let best = g.best();
    println!(
        "best: C={} gamma={} accuracy={:.2}%",
        best.c,
        best.gamma,
        best.accuracy * 100.0
    );
}

/// Write the evaluated cells as deterministic JSON: only seed-determined
/// fields (C, γ, accuracy, iterations, rounds) — wall times are excluded
/// so a sharded run's dump diffs byte-for-byte against a single-process
/// run on the same seed (the CI smoke test does exactly that).
fn write_grid_points(g: &alphaseed::coordinator::GridResult, path: &str) -> Result<()> {
    let rows = Json::arr(g.points.iter().map(|p| {
        Json::obj(vec![
            ("c", Json::num(p.c)),
            ("gamma", Json::num(p.gamma)),
            ("accuracy", Json::num(p.accuracy)),
            // u64 iteration counts can exceed 2^53; decimal strings cross
            // the JSON boundary losslessly (same rule as the wire frames)
            ("iterations", Json::str(p.iterations.to_string())),
            ("rounds", Json::num(p.rounds as f64)),
        ])
    }));
    let doc = Json::obj(vec![("points", rows)]);
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing grid points to {path}"))?;
    println!("(cells written to {path})");
    Ok(())
}

fn cmd_grid_csvc(args: &Args) -> Result<()> {
    let points_out = args.opt_str("points-out");
    if let Some(workers) = alphaseed::util::cli::worker_addrs(args)? {
        return cmd_grid_csvc_sharded(args, &workers, points_out);
    }
    reject_opt(
        args,
        "shard-bytes",
        "shard-backed row stores are wired through the distributed path; add --workers \
         (docs/DISTRIBUTED.md §2)",
    )?;
    reject_opt(
        args,
        "journal",
        "cell journaling checkpoints sharded dispatch; add --workers \
         (docs/DISTRIBUTED.md §4)",
    )?;
    for key in ["lease-secs", "heartbeat-secs", "dispatch-retries"] {
        reject_opt(
            args,
            key,
            "tunes the sharded dispatch fault-tolerance policy; add --workers \
             (docs/DISTRIBUTED.md §4)",
        )?;
    }
    reject_opt(
        args,
        "drain-secs",
        "sets the shutdown drain deadline of `worker` and `serve` processes",
    )?;
    let (ds, _, _) = load_dataset(args)?;
    let cs = args.list_or("c-grid", &[0.5, 1.0, 10.0, 100.0])?;
    let gammas = args.list_or("gamma-grid", &[0.05, 0.2, 0.8])?;
    let k = args.parse_or("k", 5usize)?;
    let seeder = args.str_or("seeder", "sir");
    let warm_c = args.flag("warm-c");
    let (policy, seed_gamma) = grid_policy_args(args, warm_c, false)?;
    let profile = run_profile(
        args,
        alphaseed::coordinator::GridOptions::default().profile,
    )?;
    args.reject_unknown()?;

    let started = std::time::Instant::now();
    let g = alphaseed::coordinator::grid_search_opts(
        &ds,
        &cs,
        &gammas,
        &alphaseed::coordinator::GridOptions {
            profile,
            k,
            seeder: seeder.clone(),
            warm_c,
            policy,
            seed_gamma,
        },
    );
    print_csvc_grid(
        &g,
        format!(
            "grid search on {} ({} cells, seeder {seeder}{}, {} s)",
            ds.name,
            g.points.len(),
            if warm_c { ", warm-C chains" } else { "" },
            fmt_secs(started.elapsed())
        ),
    );
    if let Some(path) = points_out {
        write_grid_points(&g, &path)?;
    }
    Ok(())
}

/// Parse `--lease-secs`, `--heartbeat-secs` and `--dispatch-retries`
/// into a [`DispatchPolicy`](alphaseed::coordinator::DispatchPolicy)
/// (production defaults where unset). Pure when-to-give-up knobs: none
/// of them can change a cell's bits, only which process computes it.
fn dispatch_policy_args(args: &Args) -> Result<alphaseed::coordinator::DispatchPolicy> {
    let mut policy = alphaseed::coordinator::DispatchPolicy::default();
    if let Some(s) = args.opt_parse::<f64>("lease-secs")? {
        if !s.is_finite() || s <= 0.0 {
            bail!("--lease-secs {s}: the per-cell lease must be a positive number of seconds");
        }
        policy.lease_per_cell = std::time::Duration::from_secs_f64(s);
        // a short lease implies a latency-sensitive run: shrink the base
        // lease to match instead of hiding behind the 30 s floor
        policy.lease_floor = policy.lease_floor.min(policy.lease_per_cell);
    }
    if let Some(s) = args.opt_parse::<f64>("heartbeat-secs")? {
        if !s.is_finite() || s <= 0.0 {
            bail!("--heartbeat-secs {s}: the ping interval must be a positive number of seconds");
        }
        policy.heartbeat = std::time::Duration::from_secs_f64(s);
    }
    if let Some(n) = args.opt_parse::<usize>("dispatch-retries")? {
        if n == 0 {
            bail!("--dispatch-retries 0: at least one attempt is needed to dispatch at all");
        }
        policy.retry.max_attempts = n;
    }
    Ok(policy)
}

/// Print the fault-tolerance telemetry under the sharded grid table:
/// per-worker cells/retries/failures plus the pool-wide counters.
fn print_dispatch_report(report: &alphaseed::coordinator::DispatchReport) {
    let mut t = Table::new("dispatch").header(&["worker", "cells", "retries", "failures"]);
    for w in &report.workers {
        t.row(vec![
            w.addr.clone(),
            w.cells.to_string(),
            w.retries.to_string(),
            w.failures.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "dispatch: {} retry(ies), {} lease timeout(s), {} heartbeat failure(s), \
         {} reassigned cell(s), {} in-process fallback cell(s)",
        report.retries,
        report.lease_timeouts,
        report.heartbeat_failures,
        report.reassigned_cells,
        report.fallback_cells
    );
}

/// `grid --workers a:p,b:p`: ship per-γ node groups to grid-worker
/// processes and reassemble the table. Workers evaluate independent cells
/// only, so the reuse/budget knobs that couple cells are rejected here
/// with targeted messages (docs/DISTRIBUTED.md §3–§4).
fn cmd_grid_csvc_sharded(
    args: &Args,
    workers: &[String],
    points_out: Option<String>,
) -> Result<()> {
    if args.flag("warm-c") {
        bail!(
            "--warm-c chains ascending C within a column; sharded dispatch runs independent \
             cells only (run without --workers to chain)"
        );
    }
    let (policy, seed_gamma) = grid_policy_args(args, false, false)?;
    if seed_gamma {
        bail!(
            "--seed-gamma seeds across adjacent γ cells; sharded dispatch runs independent \
             cells only (run without --workers to chain)"
        );
    }
    if !matches!(policy, BudgetPolicy::Uniform) {
        bail!(
            "--budget-policy halving pauses cells at fold boundaries, which needs the \
             in-process scheduler; sharded dispatch runs the uniform budget"
        );
    }
    let shard_bytes = args.opt_parse::<usize>("shard-bytes")?;
    // Name the dataset instead of loading it: each worker loads its own
    // copy (or fills kernel caches from disk shards) from the spec.
    let spec = if let Some(path) = args.opt_str("data") {
        alphaseed::coordinator::DatasetSpec::File { path, shard_bytes }
    } else {
        if shard_bytes.is_some() {
            bail!(
                "--shard-bytes shards a LibSVM file on disk; synthetic analogues are \
                 generated in memory (point --data at a file, e.g. via `alphaseed datagen`)"
            );
        }
        let name = args.str_or("dataset", "heart");
        if synth::spec(&name).is_none() {
            bail!("unknown dataset '{name}'");
        }
        alphaseed::coordinator::DatasetSpec::Synth {
            name,
            n: args.opt_parse::<usize>("n")?,
            seed: args.parse_or::<u64>("seed", 42)?,
        }
    };
    let cs = args.list_or("c-grid", &[0.5, 1.0, 10.0, 100.0])?;
    let gammas = args.list_or("gamma-grid", &[0.05, 0.2, 0.8])?;
    let k = args.parse_or("k", 5usize)?;
    let seeder = args.str_or("seeder", "sir");
    let dispatch_policy = dispatch_policy_args(args)?;
    let journal = args.opt_str("journal");
    reject_opt(
        args,
        "drain-secs",
        "sets the shutdown drain deadline of `worker` and `serve` processes",
    )?;
    let profile = run_profile(
        args,
        alphaseed::coordinator::GridOptions::default().profile,
    )?;
    args.reject_unknown()?;

    let started = std::time::Instant::now();
    let opts = alphaseed::coordinator::GridOptions {
        profile,
        k,
        seeder: seeder.clone(),
        warm_c: false,
        policy: BudgetPolicy::Uniform,
        seed_gamma: false,
    };
    let (g, report) = match &journal {
        Some(path) => alphaseed::coordinator::run_journaled_grid(
            &spec,
            &cs,
            &gammas,
            &opts,
            workers,
            &dispatch_policy,
            std::path::Path::new(path),
        )?,
        None => alphaseed::coordinator::run_sharded_grid_with(
            &spec,
            &cs,
            &gammas,
            &opts,
            workers,
            &dispatch_policy,
        )?,
    };
    print_csvc_grid(
        &g,
        format!(
            "sharded grid search ({} cells, seeder {seeder}, {} workers, {} s)",
            g.points.len(),
            workers.len(),
            fmt_secs(started.elapsed())
        ),
    );
    print_dispatch_report(&report);
    if let Some(path) = points_out {
        write_grid_points(&g, &path)?;
    }
    Ok(())
}

/// Run a grid-worker process: `alphaseed worker --port 7879`. A driver
/// running `grid --workers host:port,…` ships it per-γ node groups over
/// TCP/JSON lines and collects the evaluated cells back; the worker holds
/// no state between requests (docs/DISTRIBUTED.md §3).
fn cmd_worker(args: &Args) -> Result<()> {
    let port = args.parse_or("port", 7879u16)?;
    let drain = parse_drain_secs(args)?;
    args.reject_unknown()?;
    // chaos testing: ALPHASEED_FAULT_PLAN stages deterministic failures
    // in this process; a malformed plan fails startup loudly
    if alphaseed::testing::fault::install_from_env().map_err(anyhow::Error::msg)? {
        eprintln!(
            "fault: plan armed from {}",
            alphaseed::testing::fault::FAULT_PLAN_ENV
        );
    }
    let mut worker = alphaseed::coordinator::GridWorker::new();
    if let Some(deadline) = drain {
        worker = worker.with_drain_deadline(deadline);
    }
    let worker = std::sync::Arc::new(worker);
    worker.serve(&format!("127.0.0.1:{port}"), |addr| {
        println!("grid worker listening on {addr} — send {{\"op\":\"grid\",…}} lines");
    })?;
    Ok(())
}

/// Parse `--drain-secs` for the serving processes (`worker` / `serve`).
fn parse_drain_secs(args: &Args) -> Result<Option<std::time::Duration>> {
    match args.opt_parse::<f64>("drain-secs")? {
        None => Ok(None),
        Some(s) if s.is_finite() && s >= 0.0 => Ok(Some(std::time::Duration::from_secs_f64(s))),
        Some(s) => bail!("--drain-secs {s}: the drain deadline must be a non-negative number"),
    }
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let (ds, _, _) = load_dataset(args)?;
    let out = args.req_str("out")?;
    args.reject_unknown()?;
    let file = std::fs::File::create(&out)?;
    write_libsvm(&ds, std::io::BufWriter::new(file))?;
    println!(
        "wrote {} instances × {} features to {out}",
        ds.len(),
        ds.dim()
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let mut cfg = match args.opt_str("config") {
        Some(path) => RunConfig::load(&path)?,
        None => RunConfig::default(),
    };
    cfg.scale = args.parse_or("scale", cfg.scale)?;
    cfg.k = args.parse_or("k", cfg.k)?;
    cfg.rng_seed = args.parse_or("seed", cfg.rng_seed)?;
    let ks = args.list_or("ks", &[3usize, 10, 100])?;
    let loo_rounds = args.parse_or("loo-rounds", 40usize)?;
    let out_dir = args.str_or("out", "results");
    args.reject_unknown()?;
    std::fs::create_dir_all(&out_dir)?;

    let mut progress = |msg: &str| {
        eprintln!("[{}] {msg}", uptime_stamp());
    };

    let run = |name: &str,
               result: experiments::ExperimentResult,
               cfg: &RunConfig,
               out_dir: &str|
     -> Result<()> {
        print!("{}", result.table.render());
        let path = format!("{out_dir}/{name}.json");
        std::fs::write(&path, result.to_json(cfg).to_string_pretty())?;
        println!("(cells written to {path})\n");
        Ok(())
    };

    match which.as_str() {
        "table1" => run("table1", experiments::table1(&cfg, &mut progress), &cfg, &out_dir)?,
        "table2" => run("table2", experiments::table2(&cfg), &cfg, &out_dir)?,
        "table3" => run(
            "table3",
            experiments::table3(&cfg, &ks, &mut progress),
            &cfg,
            &out_dir,
        )?,
        "fig2" => run(
            "fig2",
            experiments::fig2(&cfg, loo_rounds, &mut progress),
            &cfg,
            &out_dir,
        )?,
        "all" => {
            run("table2", experiments::table2(&cfg), &cfg, &out_dir)?;
            run("table1", experiments::table1(&cfg, &mut progress), &cfg, &out_dir)?;
            run(
                "table3",
                experiments::table3(&cfg, &ks, &mut progress),
                &cfg,
                &out_dir,
            )?;
            run(
                "fig2",
                experiments::fig2(&cfg, loo_rounds, &mut progress),
                &cfg,
                &out_dir,
            )?;
        }
        other => bail!("unknown experiment '{other}' (table1|table2|table3|fig2|all)"),
    }
    Ok(())
}

/// Warm-start sweep across a C grid (Chu et al. composition with the
/// paper's fold chain): `alphaseed sweep --dataset heart --c-grid 1,4,16`.
fn cmd_sweep(args: &Args) -> Result<()> {
    let (ds, _, gamma) = load_dataset(args)?;
    let cs = args.list_or("c-grid", &[1.0, 4.0, 16.0, 64.0])?;
    let k = args.parse_or("k", 5usize)?;
    let seeder_name = args.str_or("seeder", "sir");
    let seeder = alphaseed::seeding::seeder_by_name(&seeder_name)
        .with_context(|| format!("unknown seeder '{seeder_name}'"))?;
    let fold_chain = !args.flag("no-fold-chain");
    reject_opt(
        args,
        "no-share-rows",
        "row sharing is a grid-level concern; a single warm-C sweep builds one seeding cache",
    )?;
    let profile = run_profile(args, RunProfile::default())?;
    args.reject_unknown()?;

    let reports = alphaseed::cv::run_kfold_warm_c(
        &ds,
        Kernel::rbf(gamma),
        &cs,
        k,
        seeder.as_ref(),
        alphaseed::cv::WarmCOptions {
            profile,
            fold_chain,
            ..Default::default()
        },
    );
    let mut t = Table::new(format!(
        "warm-C sweep on {} (k={k}, seeder {seeder_name}, fold_chain={fold_chain})",
        ds.name
    ))
    .header(&["C", "iterations", "total(s)", "accuracy(%)"]);
    for (rep, &c) in reports.iter().zip(&cs) {
        t.row(vec![
            format!("{c}"),
            rep.total_iterations().to_string(),
            fmt_secs(rep.total_elapsed()),
            format!("{:.2}", rep.accuracy() * 100.0),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// Train the requested `--task` model (C-SVC with optional Platt
/// calibration, ε-SVR, or one-class), install it as version 1 of a
/// [`ModelRegistry`], and serve batched predictions over TCP/JSON lines:
/// `alphaseed serve --dataset heart --port 7878 --probs`,
/// `alphaseed serve --task svr --dataset sinc`,
/// `alphaseed serve --task oneclass --nu 0.1`. A live
/// `{"op":"swap","path":…}` request hot-swaps the served model without
/// dropping connections.
fn cmd_serve(args: &Args) -> Result<()> {
    let task = args.parse_or("task", Task::CSvc)?;
    if args.flag("probs") && task != Task::CSvc {
        bail!("--probs calibrates C-SVC decision values; --task {task} serves raw decisions");
    }
    let model = match task {
        Task::CSvc => {
            let (ds, c, gamma) = load_dataset(args)?;
            let kernel = Kernel::rbf(gamma);
            let mut solver = Solver::new(KernelEval::new(ds.clone(), kernel), SmoParams::with_c(c));
            let r = solver.solve();
            let model = Model::from_result(&ds, kernel, &r);
            let scaler = if args.flag("probs") {
                println!("calibrating probabilities via SIR-seeded 5-fold CV…");
                Some(alphaseed::smo::PlattScaler::fit_from_cv(
                    &ds,
                    kernel,
                    c,
                    5,
                    &alphaseed::seeding::Sir,
                    42,
                ))
            } else {
                None
            };
            ServeModel::CSvc { model, scaler }
        }
        Task::Svr => {
            let (ds, c, gamma, epsilon) = load_regression_dataset(args)?;
            let kernel = Kernel::rbf(gamma);
            let problem = SvrProblem { c, epsilon };
            let mut solver = solver_for(&problem, &ds, kernel, SmoParams::with_c(c));
            let r = solver.solve();
            ServeModel::Svr {
                model: SvrModel::from_result(&ds, kernel, &r),
            }
        }
        Task::OneClass => {
            let seed = args.parse_or::<u64>("seed", 42)?;
            let n = args.opt_parse::<usize>("n")?;
            let outlier_frac = args.parse_or("outlier-frac", 0.1f64)?;
            let ds = synth::generate_outliers(n, outlier_frac, seed);
            let nu = args.parse_or("nu", 0.15f64)?;
            let kernel = Kernel::rbf(args.parse_or("gamma", 1.0f64)?);
            let problem = OneClassProblem { nu };
            let mut solver = solver_for(&problem, &ds, kernel, SmoParams::default());
            let beta0 = problem.initial_alpha(&ds);
            let r = solver.solve_from(beta0, None);
            ServeModel::OneClass {
                model: OneClassModel::from_result(&ds, kernel, &r),
            }
        }
        Task::Multiclass => {
            bail!("serve supports --task csvc|svr|oneclass; one-vs-one ensembles are not wired yet")
        }
    };
    let port = args.parse_or("port", 7878u16)?;
    // Serve routes batched decision fills through a per-handler-thread
    // backend; xla degrades to native per request if artifacts are absent.
    let backend = args
        .str_or("backend", "native")
        .parse::<BackendChoice>()
        .map_err(anyhow::Error::msg)?;
    let drain = parse_drain_secs(args)?;
    args.reject_unknown()?;
    // chaos testing: ALPHASEED_FAULT_PLAN stages deterministic failures
    // in this process; a malformed plan fails startup loudly
    if alphaseed::testing::fault::install_from_env().map_err(anyhow::Error::msg)? {
        eprintln!(
            "fault: plan armed from {}",
            alphaseed::testing::fault::FAULT_PLAN_ENV
        );
    }

    println!(
        "{} model trained: {} SVs ({}-d); serving on 127.0.0.1:{port}",
        model.kind(),
        model.n_sv(),
        model.dim()
    );
    let registry = std::sync::Arc::new(ModelRegistry::new(model, "startup"));
    let mut server = PredictServer::with_registry_backend(registry, backend);
    if let Some(deadline) = drain {
        server = server.with_drain_deadline(deadline);
    }
    let server = std::sync::Arc::new(server);
    server.serve(&format!("127.0.0.1:{port}"), |addr| {
        println!("listening on {addr} — send {{\"op\":\"predict\",\"rows\":[[…]]}} lines");
    })?;
    Ok(())
}

/// Load the multiclass dataset an `--task multiclass` command refers to:
/// a LibSVM file with integer class labels (`--data`), or one of the
/// synthetic generators (`--dataset blobs|rings`).
fn load_multiclass(args: &Args) -> Result<MultiDataset> {
    if let Some(path) = args.opt_str("data") {
        let ds = MultiDataset::read_libsvm(&path)
            .with_context(|| format!("loading multiclass LibSVM file {path}"))?;
        if ds.classes().len() < 2 {
            bail!(
                "{path} holds a single class ({}); one-vs-one needs at least 2 distinct labels",
                ds.classes()[0]
            );
        }
        return Ok(ds);
    }
    let seed = args.parse_or::<u64>("seed", 42)?;
    let n = args.parse_or("n", 200usize)?;
    let classes = args.parse_or("classes", 3u32)?;
    if classes < 2 {
        bail!("--classes {classes}: one-vs-one needs at least 2 classes");
    }
    match args.str_or("dataset", "blobs").as_str() {
        "blobs" => {
            let dim = args.parse_or("dim", 4usize)?;
            let sep = args.parse_or("sep", 2.0f64)?;
            Ok(alphaseed::multiclass::synth_blobs(n, dim, classes, sep, seed))
        }
        "rings" => {
            let noise = args.parse_or("noise", 0.15f64)?;
            Ok(alphaseed::multiclass::synth_rings(n, classes, noise, seed))
        }
        other => bail!(
            "unknown multiclass dataset '{other}' (blobs|rings, or --data <libsvm file> \
             with integer labels)"
        ),
    }
}

/// One-vs-one multiclass seeded CV, pairs scheduled in parallel on the
/// shared-kernel substrate: `alphaseed ovo --classes 4 --n 200 --seeder
/// sir`, `alphaseed cv --task multiclass --data iris.svm`.
fn cmd_ovo(args: &Args) -> Result<()> {
    reject_xla_backend(args, "multiclass")?;
    let ds = load_multiclass(args)?;
    let c = args.parse_or("c", 10.0f64)?;
    let gamma = args.parse_or("gamma", 0.5f64)?;
    let k = args.parse_or("k", 5usize)?;
    if k < 2 {
        bail!("--k {k}: cross-validation needs at least 2 folds");
    }
    let seeder_name = args.str_or("seeder", "sir");
    let seeder = alphaseed::seeding::seeder_by_name(&seeder_name)
        .with_context(|| format!("unknown seeder '{seeder_name}'"))?;
    let profile = run_profile(
        args,
        alphaseed::multiclass::OvoOptions::default().profile,
    )?;
    args.reject_unknown()?;

    let started = std::time::Instant::now();
    let rep = alphaseed::multiclass::cv_ovo_opts(
        &ds,
        Kernel::rbf(gamma),
        c,
        k,
        seeder.as_ref(),
        &alphaseed::multiclass::OvoOptions {
            profile,
            ..Default::default()
        },
    );
    let wall = started.elapsed();

    let mut t = Table::new(format!(
        "OvO {}-class CV on {} (n={}, k={k}, seeder {seeder_name}, wall {} s)",
        rep.classes.len(),
        rep.dataset,
        ds.len(),
        fmt_secs(wall)
    ))
    .header(&["pair", "iterations", "init(s)", "rest(s)", "pair accuracy(%)"]);
    for p in &rep.pairs {
        t.row(vec![
            format!("{} vs {}", p.class_a, p.class_b),
            p.iterations.to_string(),
            fmt_secs(p.init),
            fmt_secs(p.rest),
            format!("{:.2}", p.accuracy * 100.0),
        ]);
    }
    print!("{}", t.render());

    let mut headers: Vec<String> = vec!["truth \\ pred".into()];
    headers.extend(rep.classes.iter().map(|c| c.to_string()));
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut cm = Table::new("ensemble confusion matrix (CV test rounds)").header(&header_refs);
    for (ti, row) in rep.confusion.iter().enumerate() {
        let mut cells = vec![rep.classes[ti].to_string()];
        cells.extend(row.iter().map(|n| n.to_string()));
        cm.row(cells);
    }
    print!("{}", cm.render());
    println!(
        "ensemble CV accuracy: {:.2}%  ({} iterations, init fraction {:.2}%, {} seed fallbacks)",
        rep.accuracy() * 100.0,
        rep.total_iterations(),
        rep.init_fraction() * 100.0,
        rep.fallbacks()
    );
    Ok(())
}

/// One-vs-one multiclass (C, γ) grid search with per-γ shared row stores
/// and optional warm-C chains per pair.
fn cmd_grid_ovo(args: &Args) -> Result<()> {
    reject_xla_backend(args, "multiclass")?;
    if args.opt_str("c").is_some() || args.opt_str("gamma").is_some() {
        bail!("grid --task multiclass sweeps --c-grid/--gamma-grid; point values --c/--gamma apply to single ovo runs");
    }
    let ds = load_multiclass(args)?;
    let cs = args.list_or("c-grid", &[0.5, 1.0, 10.0, 100.0])?;
    let gammas = args.list_or("gamma-grid", &[0.05, 0.2, 0.8])?;
    let k = args.parse_or("k", 5usize)?;
    if k < 2 {
        bail!("--k {k}: cross-validation needs at least 2 folds");
    }
    let seeder = args.str_or("seeder", "sir");
    let warm_c = args.flag("warm-c");
    let (policy, seed_gamma) = grid_policy_args(args, warm_c, true)?;
    let profile = run_profile(
        args,
        alphaseed::coordinator::GridOptions::default().profile,
    )?;
    args.reject_unknown()?;

    let started = std::time::Instant::now();
    let g = alphaseed::coordinator::grid_search_ovo(
        &ds,
        &cs,
        &gammas,
        &alphaseed::coordinator::GridOptions {
            profile,
            k,
            seeder: seeder.clone(),
            warm_c,
            policy,
            seed_gamma,
        },
    );
    let mut t = Table::new(format!(
        "OvO grid search on {} ({} cells, seeder {seeder}{}, {} s)",
        ds.name,
        g.points.len(),
        if warm_c { ", warm-C chains" } else { "" },
        fmt_secs(started.elapsed())
    ))
    .header(&["C", "gamma", "ensemble accuracy(%)", "rounds", "iterations", "time(s)"]);
    for p in &g.points {
        t.row(vec![
            format!("{}", p.c),
            format!("{}", p.gamma),
            format!("{:.2}", p.accuracy * 100.0),
            p.rounds.to_string(),
            p.iterations.to_string(),
            fmt_secs(p.elapsed),
        ]);
    }
    print!("{}", t.render());
    let best = g.best();
    println!(
        "best: C={} gamma={} ensemble accuracy={:.2}%",
        best.c,
        best.gamma,
        best.accuracy * 100.0
    );
    Ok(())
}

/// Gate a freshly emitted `BENCH_*.json` against a committed baseline —
/// the CI regression check: `alphaseed benchgate --current BENCH_cv.json
/// --baseline BENCH_cv.baseline.json [--report BENCHGATE.md]`. The record
/// shape picks the gate: documents with a `serving` object (what
/// `table_serve` emits) go through the batching-ratio + p99 serve gate,
/// documents with a `kernel` object (what `micro_hotpath` emits) through
/// the naive-vs-simd row-fill speedup gate, documents with a `grid`
/// object (what `table_grid` emits) through the budget-scheduler gate
/// (halving iteration fraction, cross-γ seeding ratio, accuracy
/// identity), everything else through the
/// seeded-vs-cold iteration gate. With
/// `--report` a markdown summary is written on pass *and* fail (CI
/// uploads it as a PR artifact either way).
fn cmd_benchgate(args: &Args) -> Result<()> {
    let current_path = args.req_str("current")?;
    let baseline_path = args.req_str("baseline")?;
    let report_path = args.opt_str("report");
    let tol = GateTolerance {
        iter_ratio: args.parse_or("iter-tol", GateTolerance::default().iter_ratio)?,
        init_fraction: args.parse_or("init-frac-tol", GateTolerance::default().init_fraction)?,
    };
    let serve_tol = ServeGateTolerance {
        speedup: args.parse_or("speedup-tol", ServeGateTolerance::default().speedup)?,
    };
    args.reject_unknown()?;
    let read = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench record {path}"))?;
        Json::parse(&text).with_context(|| format!("parsing bench record {path}"))
    };
    let current = read(&current_path)?;
    let baseline = read(&baseline_path)?;
    let is_serve = baseline.get("serving").is_some() || current.get("serving").is_some();
    let is_kernel = baseline.get("kernel").is_some() || current.get("kernel").is_some();
    let is_grid = baseline.get("grid").is_some() || current.get("grid").is_some();
    if let Some(report_path) = &report_path {
        let md = if is_serve {
            render_serve_gate_report(&current_path, &baseline_path, &current, &baseline, &serve_tol)
        } else if is_kernel {
            render_kernel_gate_report(&current_path, &baseline_path, &current, &baseline)
        } else if is_grid {
            render_grid_gate_report(&current_path, &baseline_path, &current, &baseline)
        } else {
            render_gate_report(&current_path, &baseline_path, &current, &baseline, &tol)
        };
        std::fs::write(report_path, md)
            .with_context(|| format!("writing gate report {report_path}"))?;
        println!("wrote gate report to {report_path}");
    }
    let outcome = if is_serve {
        check_serve_regression(&current, &baseline, &serve_tol)
    } else if is_kernel {
        check_kernel_regression(&current, &baseline)
    } else if is_grid {
        check_grid_regression(&current, &baseline)
    } else {
        check_bench_regression(&current, &baseline, &tol)
    };
    match outcome {
        Ok(passed) => {
            for p in &passed {
                println!("PASS {p}");
            }
            println!(
                "bench gate: {} checks passed ({current_path} vs {baseline_path})",
                passed.len()
            );
            Ok(())
        }
        Err(failures) => {
            for f in &failures {
                eprintln!("FAIL {f}");
            }
            bail!(
                "bench gate: {} regression(s) in {current_path} against {baseline_path}",
                failures.len()
            )
        }
    }
}

/// Measure artifact dispatch overhead: single-row PJRT call vs native row —
/// the measurement behind the runtime's bulk/latency routing split.
fn cmd_probe(args: &Args) -> Result<()> {
    let n_iter = args.parse_or("iters", 50usize)?;
    args.reject_unknown()?;
    let ds = synth::generate("heart", Some(270), 42);
    let mut native = NativeBackend;

    let t0 = std::time::Instant::now();
    for i in 0..n_iter {
        let _ = native.kernel_rows(&ds, 0.2, &[i % ds.len()])?;
    }
    let native_per = t0.elapsed() / n_iter as u32;

    let dir = XlaBackend::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("native single row: {native_per:?}; no artifacts for the XLA probe");
        return Ok(());
    }
    let mut xla = XlaBackend::load(&dir)?;
    let _ = xla.kernel_rows(&ds, 0.2, &[0])?; // compile outside the loop
    let t1 = std::time::Instant::now();
    for i in 0..n_iter {
        let _ = xla.kernel_rows(&ds, 0.2, &[i % ds.len()])?;
    }
    let xla_per = t1.elapsed() / n_iter as u32;

    // bulk: all rows at once
    let queries: Vec<usize> = (0..ds.len()).collect();
    let t2 = std::time::Instant::now();
    let _ = xla.kernel_rows(&ds, 0.2, &queries)?;
    let xla_bulk = t2.elapsed();
    let t3 = std::time::Instant::now();
    let _ = native.kernel_rows(&ds, 0.2, &queries)?;
    let native_bulk = t3.elapsed();

    let mut t = Table::new("PJRT dispatch probe (heart analogue, n=270, d=13)")
        .header(&["path", "single row", "all rows"]);
    t.row(vec![
        "native".into(),
        format!("{native_per:?}"),
        format!("{native_bulk:?}"),
    ]);
    t.row(vec![
        "xla artifact".into(),
        format!("{xla_per:?}"),
        format!("{xla_bulk:?}"),
    ]);
    print!("{}", t.render());
    println!(
        "dispatch overhead ≈ {:?}/call → single rows stay native, bulk ops go to artifacts",
        xla_per.saturating_sub(native_per)
    );
    Ok(())
}

/// Minimal monotonic timestamp (the offline registry has no chrono).
fn uptime_stamp() -> String {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    let start = START.get_or_init(Instant::now);
    format!("{:7.1}s", start.elapsed().as_secs_f64())
}
