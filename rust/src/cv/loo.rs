//! Leave-one-out cross-validation (paper supplementary, Figure 2).
//!
//! Two seeding protocols coexist:
//!
//! - **chain** (cold / ATO / MIR / SIR): identical to the k-fold driver
//!   with k = n — each round seeds from the previous round's SVM.
//! - **from-full** (AVG / TOP): one SVM is trained on the complete dataset
//!   up front (its cost is charged to round 0), and every round seeds from
//!   that full model by removing the held-out instance — exactly the
//!   protocol of DeCoste & Wagstaff (2000) and Lee et al. (2004).
//!
//! Because full LOO is quadratic in n, `max_rounds` runs a prefix and
//! [`CvReport::extrapolated_elapsed`] scales up — the same estimation
//! method the paper uses for Adult/MNIST/Webdata.

use super::kfold::{run_kfold, CvOptions};
use super::report::{CvReport, RoundStat};
use crate::config::RunProfile;
use crate::data::{Dataset, FoldPlan};
use crate::kernel::{Kernel, KernelCache, KernelEval};
use crate::seeding::{SeedContext, Seeder};
use crate::smo::{Model, SmoParams, Solver};
use std::time::Instant;

/// Options for a leave-one-out run: the shared [`RunProfile`] knobs plus
/// the one LOO-specific field. (Earlier revisions hand-copied six profile
/// fields here; they now flow through the profile like every other
/// CV-style driver. LOO ignores the profile's grid-only knobs —
/// `share_rows`, `carry_active_set`, `cache_dtype` — which the CLI layer
/// rejects with targeted messages.)
#[derive(Debug, Clone, Default)]
pub struct LooOptions {
    /// Shared solver/runtime knobs (tolerance, shrinking, cache budgets,
    /// RNG seed, threads).
    pub profile: RunProfile,
    /// Evaluate only the first `max_rounds` held-out instances.
    pub max_rounds: Option<usize>,
}

/// Run leave-one-out CV with the given seeder, dispatching on protocol:
/// `avg`/`top` use the from-full protocol, everything else chains.
pub fn run_loo(
    full: &Dataset,
    kernel: Kernel,
    c: f64,
    seeder: &dyn Seeder,
    opts: LooOptions,
) -> CvReport {
    match seeder.name() {
        "avg" | "top" => run_loo_from_full(full, kernel, c, seeder, opts),
        _ => {
            let cv_opts = CvOptions {
                profile: opts.profile,
                max_rounds: opts.max_rounds,
                ..Default::default()
            };
            let mut rep = run_kfold(full, kernel, c, full.len(), seeder, cv_opts);
            rep.seeder = seeder.name().to_string();
            rep
        }
    }
}

fn run_loo_from_full(
    full: &Dataset,
    kernel: Kernel,
    c: f64,
    seeder: &dyn Seeder,
    opts: LooOptions,
) -> CvReport {
    let n = full.len();
    let plan = FoldPlan::leave_one_out(n);
    let rounds_to_run = opts.max_rounds.unwrap_or(n).min(n);

    // Train the full-dataset SVM once; its cost lands on round 0's "rest"
    // (the baseline methods must pay for it somewhere).
    let t_full = Instant::now();
    let params = SmoParams {
        c,
        eps: opts.profile.eps,
        shrinking: opts.profile.shrinking,
        cache_bytes: opts.profile.cache_bytes,
        threads: opts.profile.threads,
        ..Default::default()
    };
    let mut full_solver = Solver::new(KernelEval::new(full.clone(), kernel), params.clone());
    let full_result = full_solver.solve();
    let full_train_time = t_full.elapsed();
    let full_f = full_result.f_indicators(&full.y);
    let prev_train: Vec<usize> = (0..n).collect();

    let mut seed_cache = KernelCache::with_byte_budget(
        KernelEval::new(full.clone(), kernel),
        opts.profile.seed_cache_bytes,
    );

    let mut rounds = Vec::with_capacity(rounds_to_run);
    for h in 0..rounds_to_run {
        let train_idx = plan.train_indices(h);
        let train = full.select(&train_idx);
        let test = full.select(plan.test_indices(h));

        let t_init = Instant::now();
        let removed = [h];
        let ctx = SeedContext {
            full,
            kernel,
            c,
            prev_train: &prev_train,
            prev_alpha: &full_result.alpha,
            prev_f: &full_f,
            prev_b: full_result.b,
            removed: &removed,
            added: &[],
            next_train: &train_idx,
            rng_seed: opts.profile.rng_seed ^ (h as u64),
        };
        let seed = seeder.seed(&ctx, &mut seed_cache);
        let init = t_init.elapsed();

        let t_rest = Instant::now();
        let mut solver = Solver::new(KernelEval::new(train.clone(), kernel), params.clone());
        let result = solver.solve_from(seed.alpha, None);
        let model = Model::from_result(&train, kernel, &result);
        let pred = model.predict(&test);
        let correct = pred
            .iter()
            .zip(&test.y)
            .filter(|(p, y)| (*p - *y).abs() < 1e-9)
            .count();
        let grad_init = std::time::Duration::from_secs_f64(result.grad_init_secs);
        let mut rest = t_rest.elapsed().saturating_sub(grad_init);
        if h == 0 {
            rest += full_train_time;
        }

        rounds.push(RoundStat {
            round: h,
            init: init + grad_init,
            rest,
            iterations: result.iterations,
            test_correct: correct,
            test_total: test.len(),
            sq_err: 0.0,
            fell_back: seed.fell_back,
            n_sv: result.n_sv,
        });
    }

    CvReport {
        dataset: full.name.clone(),
        seeder: seeder.name().to_string(),
        k: n,
        rounds,
        partition: std::time::Duration::ZERO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::{Avg, ColdStart, Sir, Top};

    fn small() -> Dataset {
        crate::data::synth::generate("heart", Some(50), 7)
    }

    #[test]
    fn chain_loo_covers_prefix() {
        let ds = small();
        let rep = run_loo(
            &ds,
            Kernel::rbf(0.2),
            2.0,
            &Sir,
            LooOptions {
                max_rounds: Some(8),
                ..Default::default()
            },
        );
        assert_eq!(rep.rounds.len(), 8);
        assert_eq!(rep.k, 50);
        for r in &rep.rounds {
            assert_eq!(r.test_total, 1);
        }
    }

    #[test]
    fn from_full_protocols_run() {
        let ds = small();
        for seeder in [&Avg as &dyn Seeder, &Top as &dyn Seeder] {
            let rep = run_loo(
                &ds,
                Kernel::rbf(0.2),
                2.0,
                seeder,
                LooOptions {
                    max_rounds: Some(6),
                    ..Default::default()
                },
            );
            assert_eq!(rep.rounds.len(), 6, "{}", seeder.name());
            assert_eq!(rep.seeder, seeder.name());
            // from-full seeding should converge fast after round 0
            let later: u64 = rep.rounds[1..].iter().map(|r| r.iterations).sum();
            assert!(later < 50_000, "{} iterations {later}", seeder.name());
        }
    }

    #[test]
    fn seeded_loo_beats_cold_on_iterations() {
        let ds = small();
        let opts = || LooOptions {
            max_rounds: Some(10),
            ..Default::default()
        };
        let cold = run_loo(&ds, Kernel::rbf(0.2), 2.0, &ColdStart, opts());
        let avg = run_loo(&ds, Kernel::rbf(0.2), 2.0, &Avg, opts());
        // AVG seeds from the full model: per-round solves need far fewer
        // iterations than cold starts.
        assert!(
            avg.total_iterations() < cold.total_iterations(),
            "avg {} vs cold {}",
            avg.total_iterations(),
            cold.total_iterations()
        );
    }
}
