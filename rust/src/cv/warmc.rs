//! Warm start across the C grid (Chu et al., KDD 2015 — the paper's
//! "related work on alpha seeding" line of attack, implemented here as a
//! first-class feature so the two reuse dimensions compose):
//!
//! - *within* one CV run, fold h+1 seeds from fold h (the paper's
//!   contribution, any [`Seeder`]);
//! - *across* CV runs with increasing C, fold h of run C′ seeds from the
//!   **same fold** of the previous run, scaled by r = C′/C and clipped to
//!   the new box (the warm-start rule for C-SVC: the optimal α scales
//!   roughly linearly while the same instances stay support vectors).
//!
//! For a (C₁ < C₂ < … < C_m) sweep this multiplies the savings of the
//! fold chain by the savings of the C chain — the model-selection workload
//! the paper's introduction motivates.

use super::kfold::make_seed_cache;
use super::report::{CvReport, RoundStat};
use crate::config::RunProfile;
use crate::data::{Dataset, FoldPlan};
use crate::kernel::{Kernel, KernelEval, SharedKernelCache};
use crate::seeding::{balance_to_target, SeedContext, Seeder};
use crate::smo::{Model, SmoParams, Solver};
use std::sync::Arc;
use std::time::Instant;

/// Options for the warm-C sweep.
pub struct WarmCOptions {
    /// Shared solver/runtime knobs (tolerance, caches, seed, threads, …).
    /// With `profile.carry_active_set`, the carry-over rides **both**
    /// reuse dimensions: a C-chained fold carries the bounded partition
    /// of the same fold at the previous C (identity index map — the
    /// training set is the same), a fold-chained round carries it through
    /// the seeder's transfer. Validated by the solver; inert without
    /// `profile.shrinking`. `profile.share_rows` is ignored here — row
    /// sharing is decided by whoever hands in
    /// [`shared_seed_cache`](WarmCOptions::shared_seed_cache).
    pub profile: RunProfile,
    /// Also seed fold-to-fold within each C (the paper's chain). When
    /// false only the C-chain reuse is active (pure Chu et al.).
    pub fold_chain: bool,
    /// Optional process-wide row store (same dataset + kernel) backing
    /// the sweep's seeding cache; see
    /// [`CvOptions::shared_seed_cache`](super::CvOptions::shared_seed_cache).
    pub shared_seed_cache: Option<Arc<SharedKernelCache>>,
}

impl Default for WarmCOptions {
    fn default() -> Self {
        WarmCOptions {
            profile: RunProfile::default(),
            fold_chain: true,
            shared_seed_cache: None,
        }
    }
}

/// Scale a solved α from penalty `c_old` to `c_new` — the Chu et al.
/// (KDD 2015) warm-start rule adapted to the non-linear C-SVC dual.
///
/// With the ratio r = C_new / C_old, the **clip-and-rebalance** rule is
///
/// ```text
/// α'ᵢ = clip(r·αᵢ, 0, C_new)            (scale, then clip into the box)
/// Σᵢ yᵢ·α'ᵢ = 0                          (repaired by AdjustAlpha)
/// ```
///
/// Rationale: as C grows, the optimal duals of bounded support vectors
/// scale roughly linearly (αᵢ = C stays at the bound, which r·αᵢ maps to
/// exactly) while the same instances tend to remain support vectors, so
/// r·α is a near-feasible, near-optimal start. Clipping can break the
/// equality constraint Σyα = 0; the residual is redistributed over the
/// entries with remaining box headroom by
/// [`balance_to_target`](crate::seeding::balance_to_target) — the
/// paper's *AdjustAlpha* step. If the target is unreachable inside the
/// box (pathological shrink ratios), the seed falls back to α = 0, which
/// is always feasible.
pub fn rescale_alpha(alpha: &[f64], y: &[f64], c_old: f64, c_new: f64) -> Vec<f64> {
    let r = c_new / c_old;
    let mut out: Vec<f64> = alpha.iter().map(|&a| (a * r).clamp(0.0, c_new)).collect();
    // clipping can break the equality constraint; rebalance to 0
    if !balance_to_target(&mut out, y, c_new, 0.0) {
        out.iter_mut().for_each(|a| *a = 0.0);
    }
    out
}

/// Run k-fold CV for every C in `cs` (ascending recommended), reusing
/// state across both folds and C values. Returns one report per C.
pub fn run_kfold_warm_c(
    full: &Dataset,
    kernel: Kernel,
    cs: &[f64],
    k: usize,
    seeder: &dyn Seeder,
    opts: WarmCOptions,
) -> Vec<CvReport> {
    assert!(!cs.is_empty());
    let t_part = Instant::now();
    let plan = FoldPlan::stratified(full, k, opts.profile.rng_seed);
    let partition = t_part.elapsed();

    let mut seed_cache = make_seed_cache(
        full,
        kernel,
        &opts.shared_seed_cache,
        opts.profile.seed_cache_bytes,
        opts.profile.cache_dtype,
    );

    // per-fold carried state from the previous C value
    let mut prev_c_alpha: Vec<Option<Vec<f64>>> = vec![None; k];
    let mut prev_c_partition: Vec<Option<Vec<crate::smo::VarBound>>> = vec![None; k];
    let mut reports = Vec::with_capacity(cs.len());
    let carry = opts.profile.carry_active_set && opts.profile.shrinking;

    for (ci, &c) in cs.iter().enumerate() {
        let mut rounds = Vec::with_capacity(k);
        // fold-chain state within this C
        let mut prev_alpha: Vec<f64> = Vec::new();
        let mut prev_f: Vec<f64> = Vec::new();
        let mut prev_b = 0.0f64;
        let mut prev_train: Vec<usize> = Vec::new();
        let mut prev_partition: Vec<crate::smo::VarBound> = Vec::new();

        for h in 0..k {
            let train_idx = plan.train_indices(h);
            let train = full.select(&train_idx);
            let test = full.select(plan.test_indices(h));

            let t_init = Instant::now();
            // Priority: C-chain seed for this fold; else fold-chain seed;
            // else cold.
            let (alpha0, fell_back, carried) = if let Some(prev) = prev_c_alpha[h].take() {
                let a = rescale_alpha(&prev, &train.y, cs[ci - 1], c);
                // Same fold, same training set: the bounded partition of
                // the previous C maps through the identity.
                let carried = prev_c_partition[h]
                    .take()
                    .map(|part| crate::seeding::bounded_positions(&part));
                (a, false, carried)
            } else if opts.fold_chain && h > 0 {
                let trans = plan.transition(h - 1);
                let ctx = SeedContext {
                    full,
                    kernel,
                    c,
                    prev_train: &prev_train,
                    prev_alpha: &prev_alpha,
                    prev_f: &prev_f,
                    prev_b,
                    removed: &trans.removed,
                    added: &trans.added,
                    next_train: &train_idx,
                    rng_seed: opts.profile.rng_seed ^ (h as u64) ^ ((ci as u64) << 32),
                };
                let seed = seeder.seed(&ctx, &mut seed_cache);
                let carried = if carry {
                    seeder.seed_active_set(&ctx, &prev_partition)
                } else {
                    None
                };
                (seed.alpha, seed.fell_back, carried)
            } else {
                (vec![0.0; train_idx.len()], false, None)
            };
            let init = t_init.elapsed();

            let t_rest = Instant::now();
            let params = SmoParams {
                c,
                eps: opts.profile.eps,
                shrinking: opts.profile.shrinking,
                cache_bytes: opts.profile.cache_bytes,
                threads: opts.profile.threads,
                cache_dtype: opts.profile.cache_dtype,
                ..Default::default()
            };
            let mut solver = Solver::new(KernelEval::new(train.clone(), kernel), params);
            let result = solver.solve_seeded(alpha0, None, carried.as_deref());
            let model = Model::from_result(&train, kernel, &result);
            let pred = model.predict(&test);
            let correct = pred
                .iter()
                .zip(&test.y)
                .filter(|(p, y)| (*p - *y).abs() < 1e-9)
                .count();
            let grad_init = std::time::Duration::from_secs_f64(result.grad_init_secs);
            let rest = t_rest.elapsed().saturating_sub(grad_init);

            rounds.push(RoundStat {
                round: h,
                init: init + grad_init,
                rest,
                iterations: result.iterations,
                test_correct: correct,
                test_total: test.len(),
                sq_err: 0.0,
                fell_back,
                n_sv: result.n_sv,
            });

            // carry to the next C for this fold
            if ci + 1 < cs.len() {
                prev_c_alpha[h] = Some(result.alpha.clone());
                if carry {
                    prev_c_partition[h] = Some(result.partition.clone());
                }
            }
            // carry to the next fold within this C
            prev_f = result.f_indicators(&train.y);
            prev_partition = result.partition;
            prev_alpha = result.alpha;
            prev_b = result.b;
            prev_train = train_idx;
        }

        reports.push(CvReport {
            dataset: full.name.clone(),
            seeder: format!("{}+warmC", seeder.name()),
            k,
            rounds,
            partition: if ci == 0 { partition } else { Default::default() },
        });
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::{run_kfold, CvOptions};
    use crate::seeding::{ColdStart, Sir};

    #[test]
    fn rescale_preserves_feasibility() {
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let alpha = vec![0.5, 0.5, 2.0, 2.0];
        let out = rescale_alpha(&alpha, &y, 2.0, 8.0);
        let sum: f64 = out.iter().zip(&y).map(|(a, yy)| a * yy).sum();
        assert!(sum.abs() < 1e-9);
        assert!(out.iter().all(|&a| (0.0..=8.0).contains(&a)));
        // scaling up by 4: unclipped values quadruple
        assert!((out[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn rescale_down_clips_and_balances() {
        let y = vec![1.0, -1.0];
        let alpha = vec![4.0, 4.0];
        let out = rescale_alpha(&alpha, &y, 4.0, 1.0);
        assert!(out.iter().all(|&a| a <= 1.0));
        let sum: f64 = out.iter().zip(&y).map(|(a, yy)| a * yy).sum();
        assert!(sum.abs() < 1e-9);
    }

    #[test]
    fn warm_c_sweep_matches_independent_runs() {
        // A fine ascending grid (2× steps) at non-trivial C is the regime
        // Chu et al. target; coarse grids on toy problems may not win.
        let ds = crate::data::synth::generate("heart", Some(150), 5);
        let kernel = Kernel::rbf(0.2);
        let cs = [64.0, 128.0, 256.0, 512.0];
        let warm = run_kfold_warm_c(&ds, kernel, &cs, 4, &Sir, WarmCOptions::default());
        assert_eq!(warm.len(), cs.len());
        let mut warm_total = 0u64;
        let mut cold_total = 0u64;
        for (i, &c) in cs.iter().enumerate() {
            let cold = run_kfold(&ds, kernel, c, 4, &ColdStart, CvOptions::default());
            // identical accuracy per C value
            assert!(
                (warm[i].accuracy() - cold.accuracy()).abs() < 1e-9,
                "C={c}: warm {} vs cold {}",
                warm[i].accuracy(),
                cold.accuracy()
            );
            warm_total += warm[i].total_iterations();
            cold_total += cold.total_iterations();
        }
        // the sweep beats independent cold runs overall
        assert!(
            warm_total < cold_total,
            "warm sweep {warm_total} vs cold {cold_total}"
        );
    }

    #[test]
    fn pure_c_chain_without_fold_chain() {
        let ds = crate::data::synth::generate("heart", Some(80), 7);
        let kernel = Kernel::rbf(0.2);
        let warm = run_kfold_warm_c(
            &ds,
            kernel,
            &[1.0, 4.0],
            3,
            &ColdStart,
            WarmCOptions {
                fold_chain: false,
                ..Default::default()
            },
        );
        // second C's rounds all seeded from the first C
        assert!(warm[1].total_iterations() > 0);
        assert_eq!(warm[0].rounds.len(), 3);
        assert_eq!(warm[1].rounds.len(), 3);
    }
}
