//! Per-round statistics and the aggregated CV report — the exact columns
//! of the paper's Table 1 (init time / "the rest" / iterations / accuracy).

use std::time::Duration;

/// One cross-validation round.
#[derive(Debug, Clone)]
pub struct RoundStat {
    /// Round index h (0-based; round 0 always trains cold).
    pub round: usize,
    /// Alpha-initialisation time (seeding computation + warm-start gradient
    /// setup). Zero for the cold baseline.
    pub init: Duration,
    /// Everything else the paper counts in "the rest": SMO training and
    /// test-fold classification.
    pub rest: Duration,
    /// SMO iterations of this round's solve.
    pub iterations: u64,
    /// Correctly classified instances of this round's test fold. For
    /// ε-SVR rounds this counts predictions within the ε-tube of the
    /// target (the natural "correct" notion for tube regression); for
    /// one-class rounds it counts agreement with the ground-truth
    /// inlier/outlier labels.
    pub test_correct: usize,
    /// Size of this round's test fold.
    pub test_total: usize,
    /// Sum of squared test-fold residuals Σ(f(x) − z)² for ε-SVR rounds;
    /// 0 for classification and one-class rounds.
    pub sq_err: f64,
    /// The seeder gave up and fell back to cold start this round.
    pub fell_back: bool,
    /// Support vectors in this round's model.
    pub n_sv: usize,
}

/// Aggregated result of one (dataset × seeder × k) cross-validation run.
#[derive(Debug, Clone)]
pub struct CvReport {
    /// Dataset name the run was over.
    pub dataset: String,
    /// Seeder name (plus decorations like `+warmC` for the C-chain sweep).
    pub seeder: String,
    /// Number of folds k (= n for leave-one-out).
    pub k: usize,
    /// Per-round statistics, in round order (may be a prefix when
    /// `max_rounds` limited the run).
    pub rounds: Vec<RoundStat>,
    /// Fold partitioning time (counted in "the rest", as in the paper).
    pub partition: Duration,
}

impl CvReport {
    /// Σ alpha-initialisation time (paper Table 1 "init" column).
    pub fn total_init(&self) -> Duration {
        self.rounds.iter().map(|r| r.init).sum()
    }

    /// Σ training+classification time plus partitioning ("the rest").
    pub fn total_rest(&self) -> Duration {
        self.partition + self.rounds.iter().map(|r| r.rest).sum::<Duration>()
    }

    /// Total elapsed = init + rest.
    pub fn total_elapsed(&self) -> Duration {
        self.total_init() + self.total_rest()
    }

    /// Σ SMO iterations (paper Table 1 "number of iterations").
    pub fn total_iterations(&self) -> u64 {
        self.rounds.iter().map(|r| r.iterations).sum()
    }

    /// Pooled CV accuracy: total correct / total tested — how LibSVM's
    /// `svm_cross_validation` reports it.
    pub fn accuracy(&self) -> f64 {
        let correct: usize = self.rounds.iter().map(|r| r.test_correct).sum();
        let total: usize = self.rounds.iter().map(|r| r.test_total).sum();
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }

    /// Pooled cross-validation mean squared error for ε-SVR runs:
    /// Σ per-round squared residuals / Σ tested instances. 0 for
    /// classification runs (whose rounds carry no squared error).
    pub fn mse(&self) -> f64 {
        let sq: f64 = self.rounds.iter().map(|r| r.sq_err).sum();
        let total: usize = self.rounds.iter().map(|r| r.test_total).sum();
        if total == 0 {
            0.0
        } else {
            sq / total as f64
        }
    }

    /// Fraction of total elapsed time spent on alpha initialisation —
    /// the paper's "init vs the rest" split as a single ratio. 0 when
    /// nothing was measured.
    pub fn init_fraction(&self) -> f64 {
        let total = self.total_elapsed().as_secs_f64();
        if total <= 0.0 {
            0.0
        } else {
            self.total_init().as_secs_f64() / total
        }
    }

    /// Rounds where the seeder fell back to cold start.
    pub fn fallbacks(&self) -> usize {
        self.rounds.iter().filter(|r| r.fell_back).count()
    }

    /// Linear extrapolation to `k_total` rounds when only a prefix was run
    /// (the paper's method for MNIST at k=100 and the large-dataset LOO).
    pub fn extrapolated_elapsed(&self, k_total: usize) -> Duration {
        if self.rounds.is_empty() || self.rounds.len() >= k_total {
            return self.total_elapsed();
        }
        let per_round = self.total_elapsed().as_secs_f64() / self.rounds.len() as f64;
        Duration::from_secs_f64(per_round * k_total as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> CvReport {
        CvReport {
            dataset: "d".into(),
            seeder: "sir".into(),
            k: 3,
            partition: Duration::from_millis(10),
            rounds: vec![
                RoundStat {
                    round: 0,
                    init: Duration::from_millis(0),
                    rest: Duration::from_millis(100),
                    iterations: 500,
                    test_correct: 8,
                    test_total: 10,
                    sq_err: 0.5,
                    fell_back: false,
                    n_sv: 5,
                },
                RoundStat {
                    round: 1,
                    init: Duration::from_millis(5),
                    rest: Duration::from_millis(50),
                    iterations: 200,
                    test_correct: 9,
                    test_total: 10,
                    sq_err: 0.25,
                    fell_back: false,
                    n_sv: 6,
                },
                RoundStat {
                    round: 2,
                    init: Duration::from_millis(5),
                    rest: Duration::from_millis(60),
                    iterations: 250,
                    test_correct: 7,
                    test_total: 10,
                    sq_err: 0.15,
                    fell_back: true,
                    n_sv: 6,
                },
            ],
        }
    }

    #[test]
    fn aggregates() {
        let r = report();
        assert_eq!(r.total_init(), Duration::from_millis(10));
        assert_eq!(r.total_rest(), Duration::from_millis(220));
        assert_eq!(r.total_elapsed(), Duration::from_millis(230));
        assert_eq!(r.total_iterations(), 950);
        assert!((r.accuracy() - 0.8).abs() < 1e-12);
        assert_eq!(r.fallbacks(), 1);
        // Σ sq_err = 0.9 over 30 tested instances
        assert!((r.mse() - 0.03).abs() < 1e-12);
        // init 10ms of 230ms total
        assert!((r.init_fraction() - 10.0 / 230.0).abs() < 1e-9);
    }

    #[test]
    fn extrapolation() {
        let r = report();
        // 3 rounds took 230ms → 30 rounds ≈ 2300ms
        let est = r.extrapolated_elapsed(30);
        assert!((est.as_secs_f64() - 2.3).abs() < 1e-9);
        // no extrapolation needed when complete
        assert_eq!(r.extrapolated_elapsed(3), r.total_elapsed());
    }
}
