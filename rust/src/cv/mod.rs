//! Cross-validation drivers: the k-fold chain (paper §2–3) for all three
//! workloads — C-SVC, ε-SVR and one-class SVM — the leave-one-out
//! protocol (supplementary §Figure 2), and the warm-start sweep across a
//! C grid (Chu et al., composed with the fold chain).
//!
//! All drivers share two invariants:
//!
//! - the fold-to-fold seeding chain runs in order (round h seeds round
//!   h+1) — that ordering *is* the paper's method;
//! - seeding moves the solver's start, never its fixed point: per-fold
//!   accuracies (C-SVC, one-class) are identical to cold-started CV and
//!   per-fold MSE (ε-SVR) agrees to the solver's convergence tolerance.
//!
//! The C-SVC driver's intra-round parallel paths (kernel-row blocks,
//! warm-start gradient sweeps; `threads` option) additionally perform
//! bit-identical arithmetic for every thread count, so parallelism never
//! changes a result.
//!
//! The shared solver/runtime knobs of every driver live in one
//! [`RunProfile`](crate::config::RunProfile) embedded in each options
//! struct. The C-SVC and ε-SVR drivers are exposed both as one-shot
//! functions and as resumable chains ([`KfoldChain`], [`SvrKfoldChain`])
//! whose per-round stepping is what the budget-scheduled grid search
//! pauses and resumes.

mod kfold;
mod loo;
mod report;
mod warmc;

pub use kfold::{
    run_kfold, run_kfold_oneclass, run_kfold_svr, CvOptions, KfoldChain, SvrKfoldChain,
};
pub use loo::{run_loo, LooOptions};
pub use report::{CvReport, RoundStat};
pub use warmc::{rescale_alpha, run_kfold_warm_c, WarmCOptions};
