//! Cross-validation drivers: the k-fold chain (paper §2–3), the
//! leave-one-out protocol (supplementary §Figure 2), and the warm-start
//! sweep across a C grid (Chu et al., composed with the fold chain).
//!
//! All drivers share two invariants:
//!
//! - the fold-to-fold seeding chain runs in order (round h seeds round
//!   h+1) — that ordering *is* the paper's method;
//! - the intra-round parallel paths (kernel-row blocks, warm-start
//!   gradient sweeps; `threads` option) perform bit-identical arithmetic
//!   for every thread count, so parallelism never changes a result.

mod kfold;
mod loo;
mod report;
mod warmc;

pub use kfold::{run_kfold, CvOptions};
pub use loo::{run_loo, LooOptions};
pub use report::{CvReport, RoundStat};
pub use warmc::{rescale_alpha, run_kfold_warm_c, WarmCOptions};
