//! Cross-validation drivers: the k-fold chain (paper §2–3) and the
//! leave-one-out protocol (supplementary §Figure 2).

mod kfold;
mod loo;
mod report;
mod warmc;

pub use kfold::{run_kfold, CvOptions};
pub use loo::{run_loo, LooOptions};
pub use report::{CvReport, RoundStat};
pub use warmc::{rescale_alpha, run_kfold_warm_c, WarmCOptions};
