//! The k-fold cross-validation chains with alpha seeding (paper §2–3):
//! the original C-SVC driver ([`run_kfold`]) plus the ε-SVR
//! ([`run_kfold_svr`]) and one-class ([`run_kfold_oneclass`]) chains over
//! the same 𝓡/𝒯 fold transitions.
//!
//! Round 0 always trains cold (there is no previous SVM) unless the
//! caller provides a cross-γ donor ([`CvOptions::round0_seed`]); rounds
//! 1..k seed from round h−1's solution through the configured [`Seeder`]
//! (or its SVR/one-class counterpart). The paper's time accounting is
//! kept exactly: *init* = seeding computation + warm-start gradient
//! setup; *the rest* = partitioning + SMO + test-fold evaluation.
//!
//! The C-SVC and SVR chains are materialised as resumable state machines
//! ([`KfoldChain`], [`SvrKfoldChain`]): one [`step`](KfoldChain::step)
//! call runs exactly one round, so a scheduler can pause a cell after a
//! few folds, compare partial metrics across cells, and later resume the
//! survivors with all seeded state intact (the budget scheduler in
//! `coordinator/schedule.rs` does exactly this). [`run_kfold`] /
//! [`run_kfold_svr`] are thin drive-to-completion loops over the chains,
//! so a paused-and-resumed cell computes bit-for-bit the same rounds as
//! an uninterrupted run.

use super::report::{CvReport, RoundStat};
use crate::config::RunProfile;
use crate::data::{Dataset, FoldPlan};
use crate::kernel::{CacheDtype, Kernel, KernelCache, KernelEval, SharedKernelCache};
use crate::runtime::ComputeBackend;
use crate::seeding::gamma::{project_alpha_csvc, project_delta_svr};
use crate::seeding::oneclass::{check_feasible_oneclass, seed_oneclass, OneClassSeedContext};
use crate::seeding::svr::{check_feasible_delta, SvrSeedContext, SvrSeeder};
use crate::seeding::{check_feasible, SeedContext, Seeder};
use crate::smo::problem::{collapse_svr_pairs, expand_svr_pairs, svr_errors};
use crate::smo::{
    GeneralSolver, Model, OneClassModel, OneClassProblem, QpProblem, SmoParams, Solver, SvrModel,
    SvrProblem,
};
use crate::util::pool::{effective_threads, par_chunks_mut};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Kernel rows per parallel block in the warm-gradient sweeps (bounds
/// pinned-row memory at `ROW_BLOCK·n·8` bytes).
const ROW_BLOCK: usize = 64;
/// Training sets smaller than this run the sequential gradient loop (the
/// parallel hand-off would cost more than it saves). Both paths perform
/// identical arithmetic, so the cutoff never changes results.
const PAR_MIN_N: usize = 256;

/// Options for a CV run. The solver/runtime knobs every driver shares
/// live in [`profile`](CvOptions::profile); the fields here are specific
/// to a single k-fold chain.
pub struct CvOptions<'a> {
    /// Shared solver/runtime knobs (tolerance, caches, seed, threads, …).
    /// `profile.share_rows` is ignored by the fold drivers — row sharing
    /// is decided by whoever hands in
    /// [`shared_seed_cache`](CvOptions::shared_seed_cache).
    pub profile: RunProfile,
    /// Run only the first `max_rounds` rounds (paper's estimation protocol
    /// for the expensive configurations); `None` = all k.
    pub max_rounds: Option<usize>,
    /// Bulk backend for warm-start gradient init and test-fold decision
    /// values; `None` = native in-process math.
    pub backend: Option<&'a mut dyn ComputeBackend>,
    /// Optional process-wide row store (same dataset + kernel) backing
    /// this run's seeding cache, so concurrent runs over the same data —
    /// e.g. grid cells sharing a γ — compute each kernel row once. Purely
    /// a compute-sharing device: the adopted rows are the exact bits the
    /// local cache would have produced.
    pub shared_seed_cache: Option<Arc<SharedKernelCache>>,
    /// Cross-γ warm start for round 0 (the chain's only cold solve): the
    /// *donor* vector from an adjacent-γ cell's round-0 solve over the
    /// **same fold partition** — final α for C-SVC, final pair
    /// differences δ for ε-SVR. The driver projects it onto this cell's
    /// feasible set through [`seeding::gamma`](crate::seeding::gamma)
    /// (clip + rebalance) before use; an infeasible projection falls back
    /// to a cold start with `fell_back` recorded. Ignored by the
    /// one-class driver. Like every seeding transfer this moves the
    /// solver's starting point, never its fixed point.
    pub round0_seed: Option<Vec<f64>>,
}

impl Default for CvOptions<'_> {
    fn default() -> Self {
        CvOptions {
            profile: RunProfile::default(),
            max_rounds: None,
            backend: None,
            shared_seed_cache: None,
            round0_seed: None,
        }
    }
}

/// Run k-fold cross-validation of an RBF C-SVC over `full` with the given
/// seeder. Returns per-round and aggregate statistics.
pub fn run_kfold(
    full: &Dataset,
    kernel: Kernel,
    c: f64,
    k: usize,
    seeder: &dyn Seeder,
    mut opts: CvOptions,
) -> CvReport {
    let mut backend = opts.backend.take();
    let mut chain = KfoldChain::new(full, kernel, c, k, seeder, opts);
    while chain.step(backend.as_deref_mut()) {}
    chain.into_report()
}

/// A resumable C-SVC k-fold chain: each [`step`](KfoldChain::step) runs
/// one round (fold), carrying the previous round's α/gradient/partition
/// exactly as the one-shot driver does. Pausing between steps and
/// resuming later computes bit-for-bit the same rounds — the substrate
/// for budget-scheduled grid search.
pub struct KfoldChain<'a> {
    full: &'a Dataset,
    kernel: Kernel,
    c: f64,
    k: usize,
    seeder: &'a dyn Seeder,
    profile: RunProfile,
    round0_seed: Option<Vec<f64>>,
    plan: FoldPlan,
    partition: Duration,
    seed_cache: KernelCache,
    rounds_to_run: usize,
    rounds: Vec<RoundStat>,
    // Carried state from round h−1.
    prev_alpha: Vec<f64>,
    prev_f: Vec<f64>,
    prev_b: f64,
    prev_train: Vec<usize>,
    prev_partition: Vec<crate::smo::VarBound>,
    first_round_alpha: Option<Vec<f64>>,
}

impl<'a> KfoldChain<'a> {
    /// Build the chain: fold partition + (possibly shared-backed) seeding
    /// cache. No round runs yet. `opts.backend` is ignored here — the
    /// backend is handed to each [`step`](KfoldChain::step) call instead,
    /// so chains stay `Send` and can hop between scheduler workers.
    pub fn new(
        full: &'a Dataset,
        kernel: Kernel,
        c: f64,
        k: usize,
        seeder: &'a dyn Seeder,
        opts: CvOptions,
    ) -> KfoldChain<'a> {
        let t_part = Instant::now();
        let plan = FoldPlan::stratified(full, k, opts.profile.rng_seed);
        let partition = t_part.elapsed();

        // Kernel-row cache over the full dataset for the seeders — backed
        // by the process-wide shared store when the caller provides one
        // (grid cells with the same dataset + γ then compute each row
        // only once).
        let seed_cache = make_seed_cache(
            full,
            kernel,
            &opts.shared_seed_cache,
            opts.profile.seed_cache_bytes,
            opts.profile.cache_dtype,
        );

        let rounds_to_run = opts.max_rounds.unwrap_or(k).min(k);
        KfoldChain {
            full,
            kernel,
            c,
            k,
            seeder,
            profile: opts.profile,
            round0_seed: opts.round0_seed,
            plan,
            partition,
            seed_cache,
            rounds_to_run,
            rounds: Vec::with_capacity(rounds_to_run),
            prev_alpha: Vec::new(),
            prev_f: Vec::new(),
            prev_b: 0.0,
            prev_train: Vec::new(),
            prev_partition: Vec::new(),
            first_round_alpha: None,
        }
    }

    /// Rounds completed so far.
    pub fn rounds_run(&self) -> usize {
        self.rounds.len()
    }

    /// Per-round statistics of the rounds completed so far.
    pub fn rounds(&self) -> &[RoundStat] {
        &self.rounds
    }

    /// True once every scheduled round has run.
    pub fn is_done(&self) -> bool {
        self.rounds.len() >= self.rounds_to_run
    }

    /// Round 0's converged α (aligned with round 0's training set) — the
    /// donor a cross-γ neighbour projects from. `None` until round 0 has
    /// run.
    pub fn first_round_alpha(&self) -> Option<&[f64]> {
        self.first_round_alpha.as_deref()
    }

    /// Run one round. Returns `false` (without running anything) once the
    /// chain is complete. `backend` routes the warm-start gradient and
    /// test-fold decision values through a bulk [`ComputeBackend`];
    /// `None` = native in-process math.
    pub fn step(&mut self, mut backend: Option<&mut dyn ComputeBackend>) -> bool {
        if self.is_done() {
            return false;
        }
        let h = self.rounds.len();
        let (full, kernel, c) = (self.full, self.kernel, self.c);
        let train_idx = self.plan.train_indices(h);
        let train = full.select(&train_idx);
        let test = full.select(self.plan.test_indices(h));

        // ---- init phase: produce the seed α (and the carried set) --------
        let t_init = Instant::now();
        let mut gamma_seeded = false;
        let (alpha0, fell_back, carried) = if h == 0 {
            match self.round0_seed.take() {
                // Cross-γ transfer: project the adjacent cell's donor α
                // onto this cell's feasible set (clip + rebalance); an
                // unreachable projection is a recorded fallback to cold.
                Some(donor) => {
                    assert_eq!(
                        donor.len(),
                        train_idx.len(),
                        "cross-γ round0_seed length {} does not match round 0's training set {} \
                         (donor must come from the same fold partition)",
                        donor.len(),
                        train_idx.len()
                    );
                    match project_alpha_csvc(&donor, &train.y, c) {
                        Some(alpha) => {
                            gamma_seeded = true;
                            (alpha, false, None)
                        }
                        None => (vec![0.0; train_idx.len()], true, None),
                    }
                }
                None => (vec![0.0; train_idx.len()], false, None),
            }
        } else {
            let trans = self.plan.transition(h - 1);
            let ctx = SeedContext {
                full,
                kernel,
                c,
                prev_train: &self.prev_train,
                prev_alpha: &self.prev_alpha,
                prev_f: &self.prev_f,
                prev_b: self.prev_b,
                removed: &trans.removed,
                added: &trans.added,
                next_train: &train_idx,
                rng_seed: self.profile.rng_seed ^ (h as u64),
            };
            let seed = self.seeder.seed(&ctx, &mut self.seed_cache);
            debug_assert!(
                check_feasible(&seed.alpha, &train.y, c).is_ok(),
                "{} produced infeasible seed at round {h}: {:?}",
                self.seeder.name(),
                check_feasible(&seed.alpha, &train.y, c)
            );
            // Active-set carry-over rides the same transition (init cost).
            let carried = if self.profile.carry_active_set && self.profile.shrinking {
                self.seeder.seed_active_set(&ctx, &self.prev_partition)
            } else {
                None
            };
            (seed.alpha, seed.fell_back, carried)
        };

        // Warm-start gradient (part of init time — it only exists because
        // of seeding): through the bulk artifact backend when wired, else
        // through the shared seed cache, whose full-dataset rows are
        // already hot from the seeding computation and previous rounds.
        // Cross-γ-seeded round 0 has no previous-round state to reuse, so
        // its gradient is built inside the solver (charged to init below).
        let initial_g = if h > 0 && alpha0.iter().any(|&a| a > 0.0) {
            match backend.as_deref_mut() {
                Some(backend) => {
                    let sv_idx: Vec<usize> =
                        (0..train.len()).filter(|&i| alpha0[i] > 0.0).collect();
                    let sv = train.select(&sv_idx);
                    let coef: Vec<f64> =
                        sv_idx.iter().map(|&i| train.y[i] * alpha0[i]).collect();
                    match backend.kernel_matvec(&train, &sv, &coef, kernel.gamma().unwrap_or(1.0))
                    {
                        Ok(kv) => Some(
                            kv.iter()
                                .zip(&train.y)
                                .map(|(v, y)| y * v - 1.0)
                                .collect::<Vec<f64>>(),
                        ),
                        Err(_) => None, // fall through to native gradient init
                    }
                }
                None => Some(warm_gradient(
                    &mut self.seed_cache,
                    full,
                    &self.prev_train,
                    &self.prev_alpha,
                    &self.prev_f,
                    &train_idx,
                    &train.y,
                    &alpha0,
                    self.profile.threads,
                )),
            }
        } else {
            None
        };
        let init = t_init.elapsed();

        // ---- "the rest": train + classify --------------------------------
        let t_rest = Instant::now();
        let params = SmoParams {
            c,
            eps: self.profile.eps,
            shrinking: self.profile.shrinking,
            cache_bytes: self.profile.cache_bytes,
            threads: self.profile.threads,
            cache_dtype: self.profile.cache_dtype,
            ..Default::default()
        };
        let mut solver = Solver::new(KernelEval::new(train.clone(), kernel), params);
        let result = solver.solve_seeded(alpha0, initial_g, carried.as_deref());

        let model = Model::from_result(&train, kernel, &result);
        let correct = match backend.as_deref_mut() {
            Some(backend) => {
                match crate::runtime::decision_values_via(
                    backend,
                    &model.sv,
                    &model.coef,
                    model.b,
                    kernel.gamma().unwrap_or(1.0),
                    &test,
                ) {
                    Ok(vals) => vals
                        .iter()
                        .zip(&test.y)
                        .filter(|(d, y)| (if **d >= 0.0 { 1.0 } else { -1.0 }) == **y)
                        .count(),
                    Err(_) => count_correct(&model, &test),
                }
            }
            None => count_correct(&model, &test),
        };
        let mut rest = t_rest.elapsed();

        // Warm-start gradient setup that happened *inside* the solver is
        // init cost, not training cost (paper accounting). A cross-γ
        // seeded round 0 is a warm start too.
        let seeded_round = h > 0 || gamma_seeded;
        let grad_init = Duration::from_secs_f64(result.grad_init_secs);
        let init = if seeded_round { init + grad_init } else { init };
        rest = rest.saturating_sub(if seeded_round {
            grad_init
        } else {
            Default::default()
        });

        self.rounds.push(RoundStat {
            round: h,
            init,
            rest,
            iterations: result.iterations,
            test_correct: correct,
            test_total: test.len(),
            sq_err: 0.0,
            fell_back,
            n_sv: result.n_sv,
        });

        // Carry state to round h+1.
        self.prev_f = result.f_indicators(&train.y);
        self.prev_partition = result.partition;
        self.prev_alpha = result.alpha;
        self.prev_b = result.b;
        self.prev_train = train_idx;
        if h == 0 {
            self.first_round_alpha = Some(self.prev_alpha.clone());
        }
        true
    }

    /// Finish the chain into a [`CvReport`] over the rounds run so far.
    pub fn into_report(self) -> CvReport {
        CvReport {
            dataset: self.full.name.clone(),
            seeder: self.seeder.name().to_string(),
            k: self.k,
            rounds: self.rounds,
            partition: self.partition,
        }
    }
}

/// Build the (possibly shared-backed) full-dataset seeding cache — the
/// common preamble of all k-fold drivers (fold chains and warm-C sweeps).
pub(crate) fn make_seed_cache(
    full: &Dataset,
    kernel: Kernel,
    shared: &Option<Arc<SharedKernelCache>>,
    bytes: usize,
    dtype: CacheDtype,
) -> KernelCache {
    match shared {
        Some(shared) => {
            // cheap enough to check in release: adopting rows from a store
            // built for different data or kernel would silently corrupt
            // every warm-start gradient
            assert!(
                shared.n() == full.len() && shared.kernel() == kernel,
                "shared seed cache bound to a different dataset or kernel"
            );
            // dtype is inherited from the shared store (adopted rows keep
            // their storage precision)
            KernelCache::with_shared_backing(Arc::clone(shared), bytes)
        }
        None => KernelCache::with_byte_budget_dtype(
            KernelEval::new(full.clone(), kernel),
            bytes,
            dtype,
        ),
    }
}

/// Run k-fold cross-validation of an RBF **ε-SVR** over the regression
/// dataset `full` with the given pair-difference seeder — the paper's
/// chain applied to the doubled α/α* dual. Folds come from the
/// unstratified [`FoldPlan::random`] (there is no ±1 label to stratify
/// on); each round's seed δ is expanded into the doubled feasible
/// β = (max(δ,0), max(−δ,0)) and polished by the
/// [`GeneralSolver`]. The report carries the per-fold squared residuals
/// ([`CvReport::mse`]) and the init-vs-rest split
/// ([`CvReport::init_fraction`]); `test_correct` counts predictions
/// inside the ε-tube.
///
/// `opts.backend` and `opts.profile.threads` are ignored (the general
/// solver's gradient path is sequential); `opts.profile.shrinking` and
/// `opts.profile.carry_active_set` are honored exactly as in the C-SVC
/// chain — the general path shrinks through the same shared core, and
/// seeded rounds carry the previous round's bounded (α, α*) pairs as the
/// initial shrink state.
pub fn run_kfold_svr(
    full: &Dataset,
    kernel: Kernel,
    c: f64,
    epsilon: f64,
    k: usize,
    seeder: &dyn SvrSeeder,
    opts: CvOptions,
) -> CvReport {
    let mut chain = SvrKfoldChain::new(full, kernel, c, epsilon, k, seeder, opts);
    while chain.step() {}
    chain.into_report()
}

/// A resumable ε-SVR k-fold chain — [`KfoldChain`]'s counterpart over the
/// pair differences δ = α − α*. Each [`step`](SvrKfoldChain::step) runs
/// one round; pausing and resuming computes bit-for-bit the same rounds
/// as a one-shot [`run_kfold_svr`] call.
pub struct SvrKfoldChain<'a> {
    full: &'a Dataset,
    kernel: Kernel,
    c: f64,
    epsilon: f64,
    k: usize,
    seeder: &'a dyn SvrSeeder,
    profile: RunProfile,
    round0_seed: Option<Vec<f64>>,
    plan: FoldPlan,
    partition: Duration,
    seed_cache: KernelCache,
    rounds_to_run: usize,
    rounds: Vec<RoundStat>,
    // Carried state from round h−1 (pair differences + tube residuals).
    prev_delta: Vec<f64>,
    prev_err: Vec<f64>,
    prev_b: f64,
    prev_train: Vec<usize>,
    prev_partition: Vec<crate::smo::VarBound>,
    first_round_delta: Option<Vec<f64>>,
}

impl<'a> SvrKfoldChain<'a> {
    /// Build the chain (fold partition + seeding cache); no round runs
    /// yet. Panics unless `full` is a regression dataset.
    pub fn new(
        full: &'a Dataset,
        kernel: Kernel,
        c: f64,
        epsilon: f64,
        k: usize,
        seeder: &'a dyn SvrSeeder,
        opts: CvOptions,
    ) -> SvrKfoldChain<'a> {
        assert!(
            full.is_regression(),
            "run_kfold_svr needs a regression dataset (Dataset::regression)"
        );
        let t_part = Instant::now();
        let plan = FoldPlan::random(full.len(), k, opts.profile.rng_seed);
        let partition = t_part.elapsed();

        let seed_cache = make_seed_cache(
            full,
            kernel,
            &opts.shared_seed_cache,
            opts.profile.seed_cache_bytes,
            opts.profile.cache_dtype,
        );

        let rounds_to_run = opts.max_rounds.unwrap_or(k).min(k);
        SvrKfoldChain {
            full,
            kernel,
            c,
            epsilon,
            k,
            seeder,
            profile: opts.profile,
            round0_seed: opts.round0_seed,
            plan,
            partition,
            seed_cache,
            rounds_to_run,
            rounds: Vec::with_capacity(rounds_to_run),
            prev_delta: Vec::new(),
            prev_err: Vec::new(),
            prev_b: 0.0,
            prev_train: Vec::new(),
            prev_partition: Vec::new(),
            first_round_delta: None,
        }
    }

    /// Rounds completed so far.
    pub fn rounds_run(&self) -> usize {
        self.rounds.len()
    }

    /// Per-round statistics of the rounds completed so far.
    pub fn rounds(&self) -> &[RoundStat] {
        &self.rounds
    }

    /// True once every scheduled round has run.
    pub fn is_done(&self) -> bool {
        self.rounds.len() >= self.rounds_to_run
    }

    /// Round 0's converged pair differences δ — the donor a cross-γ
    /// neighbour projects from. `None` until round 0 has run.
    pub fn first_round_delta(&self) -> Option<&[f64]> {
        self.first_round_delta.as_deref()
    }

    /// Run one round; `false` (and no work) once the chain is complete.
    pub fn step(&mut self) -> bool {
        if self.is_done() {
            return false;
        }
        let h = self.rounds.len();
        let (full, kernel, c, epsilon) = (self.full, self.kernel, self.c, self.epsilon);
        let train_idx = self.plan.train_indices(h);
        let train = full.select(&train_idx);
        let test = full.select(self.plan.test_indices(h));

        // ---- init phase: produce the seed δ and expand it ---------------
        let t_init = Instant::now();
        let mut gamma_seeded = false;
        let (delta0, fell_back, carried) = if h == 0 {
            match self.round0_seed.take() {
                Some(donor) => {
                    assert_eq!(
                        donor.len(),
                        train_idx.len(),
                        "cross-γ round0_seed length {} does not match round 0's training set {} \
                         (donor must come from the same fold partition)",
                        donor.len(),
                        train_idx.len()
                    );
                    match project_delta_svr(&donor, c) {
                        Some(delta) => {
                            gamma_seeded = true;
                            (delta, false, None)
                        }
                        None => (vec![0.0; train_idx.len()], true, None),
                    }
                }
                None => (vec![0.0; train_idx.len()], false, None),
            }
        } else {
            let trans = self.plan.transition(h - 1);
            let ctx = SvrSeedContext {
                full,
                kernel,
                c,
                epsilon,
                prev_train: &self.prev_train,
                prev_delta: &self.prev_delta,
                prev_err: &self.prev_err,
                prev_b: self.prev_b,
                removed: &trans.removed,
                added: &trans.added,
                next_train: &train_idx,
                rng_seed: self.profile.rng_seed ^ (h as u64),
            };
            let seed = self.seeder.seed(&ctx, &mut self.seed_cache);
            debug_assert!(
                check_feasible_delta(&seed.delta, c).is_ok(),
                "{} produced infeasible SVR seed at round {h}: {:?}",
                self.seeder.name(),
                check_feasible_delta(&seed.delta, c)
            );
            let carried = if self.profile.carry_active_set && self.profile.shrinking {
                self.seeder.seed_active_set(&ctx, &self.prev_partition)
            } else {
                None
            };
            (seed.delta, seed.fell_back, carried)
        };
        let beta0 = expand_svr_pairs(&delta0);
        let init = t_init.elapsed();

        // ---- "the rest": train + evaluate --------------------------------
        let t_rest = Instant::now();
        let problem = SvrProblem { c, epsilon };
        let params = SmoParams {
            c,
            eps: self.profile.eps,
            shrinking: self.profile.shrinking,
            cache_bytes: self.profile.cache_bytes,
            cache_dtype: self.profile.cache_dtype,
            ..Default::default()
        };
        let mut solver =
            GeneralSolver::new(KernelEval::new(train.clone(), kernel), problem.spec(&train), params);
        let result = solver.solve_seeded(beta0, None, carried.as_deref());

        let model = SvrModel::from_result(&train, kernel, &result);
        let pred = model.predict(&test);
        let sq_err: f64 = pred
            .iter()
            .zip(&test.targets)
            .map(|(p, z)| (p - z) * (p - z))
            .sum();
        let within_tube = pred
            .iter()
            .zip(&test.targets)
            .filter(|(p, z)| (*p - *z).abs() <= epsilon)
            .count();
        let mut rest = t_rest.elapsed();

        // Warm-start gradient setup inside the solver is init cost, not
        // training cost (paper accounting), exactly as in the C-SVC chain;
        // a cross-γ seeded round 0 is a warm start too.
        let seeded_round = h > 0 || gamma_seeded;
        let grad_init = Duration::from_secs_f64(result.grad_init_secs);
        let init = if seeded_round { init + grad_init } else { init };
        rest = rest.saturating_sub(if seeded_round {
            grad_init
        } else {
            Default::default()
        });

        self.rounds.push(RoundStat {
            round: h,
            init,
            rest,
            iterations: result.iterations,
            test_correct: within_tube,
            test_total: test.len(),
            sq_err,
            fell_back,
            n_sv: model.n_sv(),
        });

        // Carry state to round h+1.
        self.prev_err = svr_errors(&result, epsilon);
        self.prev_delta = collapse_svr_pairs(&result.alpha);
        self.prev_partition = result.partition;
        self.prev_b = result.b;
        self.prev_train = train_idx;
        if h == 0 {
            self.first_round_delta = Some(self.prev_delta.clone());
        }
        true
    }

    /// Finish the chain into a [`CvReport`] over the rounds run so far.
    pub fn into_report(self) -> CvReport {
        CvReport {
            dataset: self.full.name.clone(),
            seeder: self.seeder.name().to_string(),
            k: self.k,
            rounds: self.rounds,
            partition: self.partition,
        }
    }
}

/// Run k-fold cross-validation of an RBF **one-class SVM** over `full`
/// with ν as the outlier-fraction bound. Folds are stratified on the
/// ground-truth ±1 labels so every fold carries the same contamination;
/// training itself never sees a label. With `transplant = true`, rounds
/// 1..k seed through the SIR-style one-class transplant
/// ([`seed_oneclass`]); otherwise every round starts from the LibSVM
/// ν-fraction point. `test_correct` counts agreement of the sign of the
/// decision function with the ground-truth labels.
///
/// `opts.backend`, `opts.profile.threads` and `opts.round0_seed` are
/// ignored, as in [`run_kfold_svr`]; `opts.profile.shrinking` is honored,
/// and with `opts.profile.carry_active_set` transplanted rounds carry the
/// previous round's bounded positions (through the same 𝓢-preserving
/// index transfer the transplant uses) as the solver's initial shrink
/// state.
pub fn run_kfold_oneclass(
    full: &Dataset,
    kernel: Kernel,
    nu: f64,
    k: usize,
    transplant: bool,
    opts: CvOptions,
) -> CvReport {
    let t_part = Instant::now();
    let plan = FoldPlan::stratified(full, k, opts.profile.rng_seed);
    let partition = t_part.elapsed();

    let mut seed_cache = make_seed_cache(
        full,
        kernel,
        &opts.shared_seed_cache,
        opts.profile.seed_cache_bytes,
        opts.profile.cache_dtype,
    );

    let rounds_to_run = opts.max_rounds.unwrap_or(k).min(k);
    let mut rounds = Vec::with_capacity(rounds_to_run);
    let problem = OneClassProblem { nu };

    let mut prev_alpha: Vec<f64> = Vec::new();
    let mut prev_train: Vec<usize> = Vec::new();
    let mut prev_partition: Vec<crate::smo::VarBound> = Vec::new();

    for h in 0..rounds_to_run {
        let train_idx = plan.train_indices(h);
        let train = full.select(&train_idx);
        let test = full.select(plan.test_indices(h));

        // ---- init phase --------------------------------------------------
        let t_init = Instant::now();
        let (alpha0, fell_back, carried) = if h == 0 || !transplant {
            (problem.initial_alpha(&train), false, None)
        } else {
            let trans = plan.transition(h - 1);
            let ctx = OneClassSeedContext {
                full,
                kernel,
                nu,
                prev_train: &prev_train,
                prev_alpha: &prev_alpha,
                removed: &trans.removed,
                added: &trans.added,
                next_train: &train_idx,
            };
            let seed = seed_oneclass(&ctx, &mut seed_cache);
            debug_assert!(
                check_feasible_oneclass(&seed.alpha, nu).is_ok(),
                "one-class transplant produced infeasible seed at round {h}: {:?}",
                check_feasible_oneclass(&seed.alpha, nu)
            );
            // The transplant copies α_𝓢 unchanged, so the carried bounded
            // positions use the same 𝓢-preserving transfer as the α copy.
            let carried = (opts.profile.carry_active_set && opts.profile.shrinking).then(|| {
                crate::seeding::carry_bounded_positions(
                    &prev_train,
                    &prev_partition,
                    &train_idx,
                )
            });
            (seed.alpha, seed.fell_back, carried)
        };
        let init = t_init.elapsed();

        // ---- "the rest" --------------------------------------------------
        let t_rest = Instant::now();
        let params = SmoParams {
            eps: opts.profile.eps,
            shrinking: opts.profile.shrinking,
            cache_bytes: opts.profile.cache_bytes,
            cache_dtype: opts.profile.cache_dtype,
            ..Default::default()
        };
        let mut solver =
            GeneralSolver::new(KernelEval::new(train.clone(), kernel), problem.spec(&train), params);
        let result = solver.solve_seeded(alpha0, None, carried.as_deref());

        let model = OneClassModel::from_result(&train, kernel, &result);
        let pred = model.predict(&test);
        let correct = pred
            .iter()
            .zip(&test.y)
            .filter(|(p, y)| (*p - *y).abs() < 1e-9)
            .count();
        let mut rest = t_rest.elapsed();

        // The ν-fraction cold start's initial gradient is intrinsic
        // training cost (it exists with or without seeding, unlike the
        // C-SVC α = 0 start), so only *transplanted* rounds move the
        // solver's gradient setup into the init column.
        let grad_init = Duration::from_secs_f64(result.grad_init_secs);
        let seeded_round = h > 0 && transplant;
        let init = if seeded_round { init + grad_init } else { init };
        rest = rest.saturating_sub(if seeded_round {
            grad_init
        } else {
            Default::default()
        });

        rounds.push(RoundStat {
            round: h,
            init,
            rest,
            iterations: result.iterations,
            test_correct: correct,
            test_total: test.len(),
            sq_err: 0.0,
            fell_back,
            n_sv: result.n_sv,
        });

        prev_partition = result.partition;
        prev_alpha = result.alpha;
        prev_train = train_idx;
    }

    CvReport {
        dataset: full.name.clone(),
        seeder: (if transplant { "transplant" } else { "cold" }).to_string(),
        k,
        rounds,
        partition,
    }
}

/// Gᵢ = Σⱼ αⱼQᵢⱼ − 1 over the round's training set, computed from the
/// *full-dataset* kernel-row cache (global indices). Rows touched by the
/// seeders and earlier rounds are already resident, so by round 2–3 the
/// warm-start gradient is nearly free — the native analogue of routing
/// the bulk matvec to the AOT artifact.
///
/// With `threads > 1` and enough work, support vectors are processed in
/// kernel-row blocks (rows evaluated concurrently) and the sweep over t
/// is chunked across threads. Each `g[t]` accumulates its terms in the
/// same ascending-j order as the sequential loop — bit-identical output
/// for every thread count.
fn gradient_via_cache(
    cache: &mut KernelCache,
    full: &Dataset,
    train_idx: &[usize],
    train_y: &[f64],
    alpha: &[f64],
    threads: usize,
) -> Vec<f64> {
    let n = train_idx.len();
    let threads = effective_threads(threads);
    let mut g = vec![-1.0f64; n];
    let svs: Vec<usize> = (0..alpha.len()).filter(|&j| alpha[j] > 0.0).collect();
    if threads <= 1 || n < PAR_MIN_N || svs.len() < 2 {
        for &j in &svs {
            let gj = train_idx[j];
            let coef = alpha[j] * full.y[gj];
            let row = cache.row(gj);
            // hoist the dtype match: the f64 tier runs the exact
            // historical slice loop (bit-identity pin)
            match row.as_f64() {
                Some(r) => {
                    for (t, &gt) in train_idx.iter().enumerate() {
                        g[t] += train_y[t] * coef * r[gt];
                    }
                }
                None => {
                    for (t, &gt) in train_idx.iter().enumerate() {
                        g[t] += train_y[t] * coef * row.get(gt);
                    }
                }
            }
        }
        return g;
    }
    let chunk = (n / (threads * 4)).max(64);
    for block in svs.chunks(ROW_BLOCK) {
        let gjs: Vec<usize> = block.iter().map(|&j| train_idx[j]).collect();
        let rows = cache.rows_block(&gjs, threads);
        par_chunks_mut(threads, &mut g, chunk, |_c, start, piece| {
            for (off, slot) in piece.iter_mut().enumerate() {
                let t = start + off;
                let gt = train_idx[t];
                let mut acc = *slot;
                for (b, &j) in block.iter().enumerate() {
                    let coef = alpha[j] * full.y[train_idx[j]];
                    acc += train_y[t] * coef * rows[b].get(gt);
                }
                *slot = acc;
            }
        });
    }
    g
}

/// Warm-start gradient, picking between two strategies:
///
/// - **delta** — SIR/MIR keep α_𝓢 unchanged, so for a carried-over
///   instance t the new gradient is the old one plus the contribution of
///   the *changed* dual coefficients only (𝓡 dropping to zero, 𝒯 gaining
///   weight): G′_t = G_t + Σ_{Δcoef_j ≠ 0} y_t·Δcoef_j·K(t, j). Fresh 𝒯
///   instances get one kernel row each. Cost ≈ (|Δ| + |𝒯|) rows.
/// - **from-scratch** — Σ over all support vectors; cost ≈ n_sv rows.
///
/// The cheaper one (by row count) is chosen per round; both pull rows from
/// the shared full-dataset LRU. Like [`gradient_via_cache`], both
/// strategies run their row fetches and accumulation sweeps across
/// `threads` workers with bit-identical arithmetic.
#[allow(clippy::too_many_arguments)]
fn warm_gradient(
    cache: &mut KernelCache,
    full: &Dataset,
    prev_train: &[usize],
    prev_alpha: &[f64],
    prev_f: &[f64],
    next_train: &[usize],
    next_y: &[f64],
    alpha0: &[f64],
    threads: usize,
) -> Vec<f64> {
    let n = next_train.len();
    // Changed coefficients by global index: coef = y·α; Δ = new − old.
    // Collect per global index over the union of both training sets.
    let mut delta: Vec<(usize, f64)> = Vec::new();
    let mut fresh: Vec<usize> = Vec::new(); // next positions not in prev
    // old coef lookup (prev is sorted)
    let old_coef = |gi: usize| -> Option<f64> {
        prev_train
            .binary_search(&gi)
            .ok()
            .map(|p| prev_alpha[p] * full.y[gi])
    };
    // instances leaving the training set (in prev, not in next)
    for (p, &gi) in prev_train.iter().enumerate() {
        if next_train.binary_search(&gi).is_err() {
            let c = prev_alpha[p] * full.y[gi];
            if c != 0.0 {
                delta.push((gi, -c));
            }
        }
    }
    for (t, &gi) in next_train.iter().enumerate() {
        let nc = alpha0[t] * full.y[gi];
        match old_coef(gi) {
            Some(oc) => {
                if (nc - oc).abs() > 0.0 {
                    delta.push((gi, nc - oc));
                }
            }
            None => {
                // fresh instance: its own row is recomputed in full below,
                // but its coefficient still perturbs every carried row
                if nc != 0.0 {
                    delta.push((gi, nc));
                }
                fresh.push(t);
            }
        }
    }

    let n_sv = alpha0.iter().filter(|&&a| a > 0.0).count();
    if delta.len() + fresh.len() >= n_sv {
        // from-scratch is cheaper
        return gradient_via_cache(cache, full, next_train, next_y, alpha0, threads);
    }

    let threads = effective_threads(threads);
    let parallel = threads > 1 && n >= PAR_MIN_N;

    // base: carry G over from prev (G_t = y_t · f_t), −1 for fresh rows
    let mut g = vec![0.0f64; n];
    for (t, &gi) in next_train.iter().enumerate() {
        match prev_train.binary_search(&gi) {
            Ok(p) => g[t] = next_y[t] * prev_f[p],
            Err(_) => g[t] = -1.0,
        }
    }
    // apply changed coefficients to carried rows
    if parallel && delta.len() >= 2 {
        let chunk = (n / (threads * 4)).max(64);
        for dblock in delta.chunks(ROW_BLOCK) {
            let gjs: Vec<usize> = dblock.iter().map(|&(gj, _)| gj).collect();
            let rows = cache.rows_block(&gjs, threads);
            par_chunks_mut(threads, &mut g, chunk, |_c, start, piece| {
                for (off, slot) in piece.iter_mut().enumerate() {
                    let t = start + off;
                    let gt = next_train[t];
                    let mut acc = *slot;
                    for (b, &(_, dc)) in dblock.iter().enumerate() {
                        // fresh rows get the full sum below instead
                        acc += next_y[t] * dc * rows[b].get(gt);
                    }
                    *slot = acc;
                }
            });
        }
    } else {
        for &(gj, dc) in &delta {
            let row = cache.row(gj);
            match row.as_f64() {
                Some(r) => {
                    for (t, &gt) in next_train.iter().enumerate() {
                        // fresh rows get the full sum below instead
                        g[t] += next_y[t] * dc * r[gt];
                    }
                }
                None => {
                    for (t, &gt) in next_train.iter().enumerate() {
                        g[t] += next_y[t] * dc * row.get(gt);
                    }
                }
            }
        }
    }
    // fresh 𝒯 instances: full sum over the new solution's SVs via one row
    if parallel && fresh.len() >= 2 {
        // blocked like every other parallel path, so pinned-row memory
        // stays bounded at ROW_BLOCK·n·8 bytes
        for fchunk in fresh.chunks(ROW_BLOCK) {
            let gts: Vec<usize> = fchunk.iter().map(|&t| next_train[t]).collect();
            let rows = cache.rows_block(&gts, threads);
            let accs = crate::util::pool::scoped_map(threads, fchunk.len(), |fi| {
                let t = fchunk[fi];
                let row = &rows[fi];
                let mut acc = -1.0f64;
                for (j, &gj) in next_train.iter().enumerate() {
                    if alpha0[j] > 0.0 {
                        acc += next_y[t] * alpha0[j] * full.y[gj] * row.get(gj);
                    }
                }
                acc
            });
            for (&t, acc) in fchunk.iter().zip(accs) {
                g[t] = acc;
            }
        }
    } else {
        for &t in &fresh {
            let gt = next_train[t];
            let row = cache.row(gt);
            let mut acc = -1.0f64;
            for (j, &gj) in next_train.iter().enumerate() {
                if alpha0[j] > 0.0 {
                    acc += next_y[t] * alpha0[j] * full.y[gj] * row.get(gj);
                }
            }
            g[t] = acc;
        }
    }
    g
}

fn count_correct(model: &Model, test: &Dataset) -> usize {
    model
        .predict(test)
        .iter()
        .zip(&test.y)
        .filter(|(p, y)| (*p - *y).abs() < 1e-9)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seeding::{ColdStart, Mir, Sir};

    fn heart() -> Dataset {
        crate::data::synth::generate("heart", Some(150), 42)
    }

    #[test]
    fn cold_cv_runs_all_rounds() {
        let ds = heart();
        let rep = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 5, &ColdStart, CvOptions::default());
        assert_eq!(rep.rounds.len(), 5);
        assert_eq!(rep.k, 5);
        // every instance tested exactly once
        let total: usize = rep.rounds.iter().map(|r| r.test_total).sum();
        assert_eq!(total, ds.len());
        // cold start has zero meaningful init time per round
        assert!(rep.total_init().as_secs_f64() < 0.05);
    }

    #[test]
    fn sir_fewer_iterations_same_accuracy() {
        let ds = heart();
        let cold = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 5, &ColdStart, CvOptions::default());
        let sir = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 5, &Sir, CvOptions::default());
        assert!(
            sir.total_iterations() < cold.total_iterations(),
            "SIR {} vs cold {}",
            sir.total_iterations(),
            cold.total_iterations()
        );
        // The paper's headline: identical accuracy.
        assert!(
            (sir.accuracy() - cold.accuracy()).abs() < 1e-9,
            "accuracy differs: sir {} cold {}",
            sir.accuracy(),
            cold.accuracy()
        );
    }

    #[test]
    fn mir_matches_cold_accuracy() {
        let ds = heart();
        let cold = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 4, &ColdStart, CvOptions::default());
        let mir = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 4, &Mir, CvOptions::default());
        assert!((mir.accuracy() - cold.accuracy()).abs() < 1e-9);
        assert!(mir.total_iterations() <= cold.total_iterations());
    }

    #[test]
    fn max_rounds_prefix() {
        let ds = heart();
        let rep = run_kfold(
            &ds,
            Kernel::rbf(0.2),
            2.0,
            10,
            &ColdStart,
            CvOptions {
                max_rounds: Some(3),
                ..Default::default()
            },
        );
        assert_eq!(rep.rounds.len(), 3);
        assert!(rep.extrapolated_elapsed(10) > rep.total_elapsed());
    }

    #[test]
    fn round0_identical_across_seeders() {
        // Round 0 is always cold → same iteration count for any seeder.
        let ds = heart();
        let a = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 4, &ColdStart, CvOptions::default());
        let b = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 4, &Sir, CvOptions::default());
        assert_eq!(a.rounds[0].iterations, b.rounds[0].iterations);
    }

    #[test]
    fn stepped_chain_bit_identical_to_one_shot_run() {
        // Pause/resume is the halving scheduler's substrate: stepping a
        // chain one round at a time must reproduce the one-shot driver
        // exactly (iterations, accuracy, fold sizes).
        let ds = heart();
        let whole = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 5, &Sir, CvOptions::default());
        let mut chain = KfoldChain::new(&ds, Kernel::rbf(0.2), 2.0, 5, &Sir, CvOptions::default());
        // run 2 rounds, "pause", inspect, then resume to completion
        assert!(chain.step(None));
        assert!(chain.step(None));
        assert_eq!(chain.rounds_run(), 2);
        assert!(chain.first_round_alpha().is_some());
        while chain.step(None) {}
        let stepped = chain.into_report();
        assert_eq!(whole.rounds.len(), stepped.rounds.len());
        for (a, b) in whole.rounds.iter().zip(&stepped.rounds) {
            assert_eq!(a.iterations, b.iterations, "round {}", a.round);
            assert_eq!(a.test_correct, b.test_correct, "round {}", a.round);
            assert_eq!(a.test_total, b.test_total, "round {}", a.round);
        }
    }

    #[test]
    fn gamma_seeded_round0_preserves_results() {
        // Seed round 0 from an adjacent γ's round-0 solution: the chain
        // must converge to the same fold accuracies as a cold start (the
        // projection moves the starting point, never the fixed point).
        let ds = heart();
        let tight = || CvOptions {
            profile: RunProfile::default().with_eps(1e-6),
            ..Default::default()
        };
        let mut donor_chain = KfoldChain::new(&ds, Kernel::rbf(0.25), 2.0, 5, &Sir, tight());
        assert!(donor_chain.step(None));
        let donor = donor_chain.first_round_alpha().unwrap().to_vec();

        let cold = run_kfold(&ds, Kernel::rbf(0.2), 2.0, 5, &Sir, tight());
        let seeded = run_kfold(
            &ds,
            Kernel::rbf(0.2),
            2.0,
            5,
            &Sir,
            CvOptions {
                round0_seed: Some(donor),
                ..tight()
            },
        );
        assert_eq!(cold.rounds.len(), seeded.rounds.len());
        for (a, b) in cold.rounds.iter().zip(&seeded.rounds) {
            assert_eq!(
                a.test_correct, b.test_correct,
                "round {}: cross-γ seed changed a fold accuracy",
                a.round
            );
        }
    }

    #[test]
    fn svr_cv_runs_all_rounds_and_fits() {
        let ds = crate::data::synth::generate_regression("sinc", Some(100), 42);
        let rep = run_kfold_svr(
            &ds,
            Kernel::rbf(0.5),
            10.0,
            0.05,
            5,
            &crate::seeding::svr::SvrCold,
            CvOptions::default(),
        );
        assert_eq!(rep.rounds.len(), 5);
        let total: usize = rep.rounds.iter().map(|r| r.test_total).sum();
        assert_eq!(total, ds.len());
        // a smooth 1-d function at these hyper-parameters fits well
        assert!(rep.mse() < 0.1, "CV MSE {}", rep.mse());
    }

    #[test]
    fn seeded_svr_fewer_iterations_same_mse() {
        let ds = crate::data::synth::generate_regression("sinc", Some(120), 42);
        let run = |name: &str| {
            let seeder = crate::seeding::svr::svr_seeder_by_name(name).unwrap();
            run_kfold_svr(
                &ds,
                Kernel::rbf(0.5),
                10.0,
                0.05,
                5,
                seeder.as_ref(),
                CvOptions {
                    // a tight tolerance pins the fixed point so the
                    // same-result guarantee is visible on a continuous
                    // metric (see docs/SEEDING.md §3)
                    profile: RunProfile::default().with_eps(1e-6),
                    ..Default::default()
                },
            )
        };
        let cold = run("cold");
        let sir = run("sir");
        assert!(
            sir.total_iterations() < cold.total_iterations(),
            "SIR {} vs cold {}",
            sir.total_iterations(),
            cold.total_iterations()
        );
        // the paper's same-result guarantee, held to solver tolerance
        let rel = (sir.mse() - cold.mse()).abs() / cold.mse().max(1e-12);
        assert!(rel < 1e-3, "MSE diverged: sir {} cold {}", sir.mse(), cold.mse());
    }

    #[test]
    fn oneclass_cv_detects_outliers() {
        let ds = crate::data::synth::generate_outliers(Some(200), 0.1, 42);
        let rep = run_kfold_oneclass(&ds, Kernel::rbf(1.0), 0.15, 5, false, CvOptions::default());
        assert_eq!(rep.rounds.len(), 5);
        // far-field outliers vs a tight blob: well above chance
        assert!(rep.accuracy() > 0.8, "one-class accuracy {}", rep.accuracy());
    }

    #[test]
    fn oneclass_transplant_matches_cold_accuracy() {
        let ds = crate::data::synth::generate_outliers(Some(200), 0.1, 42);
        // tight solver eps pins the fixed point so the discrete accuracy
        // comparison cannot flip on a boundary-grazing decision value
        let opts = || CvOptions {
            profile: RunProfile::default().with_eps(1e-6),
            ..Default::default()
        };
        let cold = run_kfold_oneclass(&ds, Kernel::rbf(1.0), 0.15, 5, false, opts());
        let warm = run_kfold_oneclass(&ds, Kernel::rbf(1.0), 0.15, 5, true, opts());
        assert_eq!(cold.accuracy(), warm.accuracy(), "accuracy must not change");
        assert!(
            warm.total_iterations() <= cold.total_iterations(),
            "transplant {} vs cold {}",
            warm.total_iterations(),
            cold.total_iterations()
        );
    }
}
