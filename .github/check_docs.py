#!/usr/bin/env python3
"""Docs consistency gate.

Two checks, run from the repo root:

1. every relative markdown link in README.md and docs/*.md resolves to a
   file that exists;
2. every "FOO.md §N[.M]" citation — in the markdown docs *and* in the
   Rust sources' rustdoc/comments/error strings — names a numbered
   heading that actually exists in docs/FOO.md, so renumbering a section
   without chasing its references fails CI instead of rotting silently.
"""

import pathlib
import re
import sys

errors = []

markdown = [pathlib.Path("README.md"), *sorted(pathlib.Path("docs").glob("*.md"))]

# 1. relative links: [text](target) with URLs and pure anchors skipped
link = re.compile(r"\]\(([^)#\s]+)(?:#[^)]*)?\)")
for md in markdown:
    for m in link.finditer(md.read_text()):
        target = m.group(1)
        if "://" in target:
            continue
        if not (md.parent / target).exists():
            errors.append(f"{md}: broken link {target}")

# 2. numbered headings per doc: "## 3. Title" / "### 3.9 Title" -> "3"/"3.9"
heading = re.compile(r"^#+\s+(\d+(?:\.\d+)*)", re.M)
headings = {
    md.name: set(heading.findall(md.read_text()))
    for md in pathlib.Path("docs").glob("*.md")
}

# citations: the doc name with at most a few punctuation chars before the §
cite = re.compile(r"([A-Z][A-Z_]*\.md)[^\n§]{0,20}§\s*(\d+(?:\.\d+)*)")
sources = markdown + [
    *sorted(pathlib.Path("rust/src").rglob("*.rs")),
    *sorted(pathlib.Path("rust/tests").rglob("*.rs")),
]
for f in sources:
    for name, sec in cite.findall(f.read_text()):
        if name not in headings:
            errors.append(f"{f}: cites {name}, which is not in docs/")
        elif sec not in headings[name]:
            errors.append(f"{f}: cites {name} §{sec}, but that heading does not exist")

if errors:
    print("\n".join(errors))
    sys.exit(1)
count = sum(len(s) for s in headings.values())
print(f"docs check clean ({len(markdown)} markdown files, {count} numbered headings)")
